//! The executable-kernel library: each builder runs its algorithm over
//! instrumented device arrays and returns the recorded kernel streams.
//!
//! Conventions: all sizes are powers of two (so grids divide exactly
//! and torus wrap-around is a mask), one representative wavefront
//! executes per kernel, and waves own *contiguous* chunk ranges so
//! iteration-to-iteration strides model streaming access rather than
//! the giant grid-stride hops a round-robin split would record.  Array
//! fills are seeded by [`crate::util::mix`], so contents — and for
//! `spmv-ella`, the gather addresses derived from them — are
//! deterministic.

use super::{record_kernel, Device, RecordedKernel};
use crate::util::mix;

/// Deterministic fill value for element `i` of a seeded array.
fn f32_at(seed: u64, i: usize) -> f32 {
    ((mix(seed ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)) >> 40) as f32)
        / (1u64 << 24) as f32
}

/// Split `chunks` contiguous 64-element chunks between waves: each wave
/// owns `trips` consecutive chunks.  Returns `(waves, trips)`; both
/// divide exactly because everything is a power of two, and `trips` is
/// kept >= 8 where possible so the stride estimator sees several
/// iteration deltas per site.
fn grid_1d(chunks: u64, max_waves: u64) -> (u64, u64) {
    let waves = (chunks / 8).clamp(1, max_waves);
    (waves, chunks / waves)
}

/// `c[i] = a[i] + b[i]` over `n` elements: the canonical streaming,
/// memory-bound kernel (2 coalesced loads + 1 store per element).
pub(super) fn vectoradd(n: u32) -> Vec<RecordedKernel> {
    let n = n as u64;
    let mut dev = Device::new();
    let a = dev.alloc("a", n as usize, |i| f32_at(1, i));
    let b = dev.alloc("b", n as usize, |i| f32_at(2, i));
    let mut c = dev.alloc("c", n as usize, |_| 0.0f32);
    let (waves, trips) = grid_1d(n / 64, 4096);
    let k = record_kernel("vectoradd", waves, |ctx| {
        // wave 0 owns chunks 0..trips
        ctx.for_n(trips, |ctx, t| {
            let e0 = t * 64;
            ctx.salu(2);
            let av = ctx.load("a", &a, |l| e0 + l as u64);
            let bv = ctx.load("b", &b, |l| e0 + l as u64);
            ctx.fp(1);
            ctx.store("c", &mut c, |l| e0 + l as u64, |l| {
                av[l as usize] + bv[l as usize]
            });
        });
    });
    vec![k]
}

/// Dense `n*n` matmul: each wave computes one 8x8 output tile, lane `l`
/// owns element `(l/8, l%8)`; the k-loop walks A rows (unit stride) and
/// B columns (stride `4n`) — the classic compute-bound mix.
pub(super) fn matmul(n: u32) -> Vec<RecordedKernel> {
    let n = n as u64;
    let mut dev = Device::new();
    let a = dev.alloc("a", (n * n) as usize, |i| f32_at(3, i));
    let b = dev.alloc("b", (n * n) as usize, |i| f32_at(4, i));
    let mut c = dev.alloc("c", (n * n) as usize, |_| 0.0f32);
    let waves = (n / 8) * (n / 8);
    let k = record_kernel("matmul", waves, |ctx| {
        // wave 0 computes the tile at (0, 0)
        let mut acc = [0.0f32; 64];
        ctx.for_n(n / 8, |ctx, kb| {
            for kk in 0..8u64 {
                let kidx = kb * 8 + kk;
                let av = ctx.load("a", &a, |l| (l as u64 / 8) * n + kidx);
                let bv = ctx.load("b", &b, |l| kidx * n + (l as u64 % 8));
                ctx.fp(1);
                for l in 0..64 {
                    acc[l] += av[l] * bv[l];
                }
            }
            ctx.salu(1);
        });
        ctx.store("c", &mut c, |l| (l as u64 / 8) * n + (l as u64 % 8), |l| {
            acc[l as usize]
        });
    });
    vec![k]
}

/// Naive `n*n` transpose: coalesced row reads, column writes scattered
/// across `n` cache lines (fan 16) — a bandwidth/divergence stressor.
pub(super) fn transpose(n: u32) -> Vec<RecordedKernel> {
    let n = n as u64;
    let mut dev = Device::new();
    let a = dev.alloc("a", (n * n) as usize, |i| f32_at(5, i));
    let mut b = dev.alloc("b", (n * n) as usize, |_| 0.0f32);
    let (waves, trips) = grid_1d(n * n / 64, 2048);
    let k = record_kernel("transpose", waves, |ctx| {
        ctx.for_n(trips, |ctx, t| {
            let e0 = t * 64;
            ctx.salu(2);
            let av = ctx.load("a", &a, |l| e0 + l as u64);
            ctx.store(
                "b",
                &mut b,
                |l| {
                    let e = e0 + l as u64;
                    (e % n) * n + e / n
                },
                |l| av[l as usize],
            );
        });
    });
    vec![k]
}

/// Two-kernel sum reduction: `reduce_partial` accumulates per-lane
/// partials over the input, `reduce_final` folds the partial array and
/// the 64 lanes down with a barrier-separated tree — a multi-kernel
/// workload with a wide then narrow launch.
pub(super) fn reduce(n: u32) -> Vec<RecordedKernel> {
    let n = n as u64;
    let mut dev = Device::new();
    let a = dev.alloc("a", n as usize, |i| f32_at(6, i));
    let (waves, trips) = grid_1d(n / 64, 1024);
    let mut partial = dev.alloc("partial", (waves * 64) as usize, |_| 0.0f32);
    let mut out = dev.alloc("out", 64, |_| 0.0f32);
    let k0 = record_kernel("reduce_partial", waves, |ctx| {
        let mut acc = [0.0f32; 64];
        ctx.for_n(trips, |ctx, t| {
            let e0 = t * 64;
            let av = ctx.load("a", &a, |l| e0 + l as u64);
            ctx.fp(1);
            for l in 0..64 {
                acc[l] += av[l];
            }
        });
        ctx.salu(1);
        ctx.store("partial", &mut partial, |l| l as u64, |l| acc[l as usize]);
    });
    let k1 = record_kernel("reduce_final", 1, |ctx| {
        let mut acc = [0.0f32; 64];
        ctx.for_n(waves, |ctx, w| {
            let av = ctx.load("partial", &partial, |l| w * 64 + l as u64);
            ctx.fp(1);
            for l in 0..64 {
                acc[l] += av[l];
            }
        });
        let mut s = 32;
        while s >= 1 {
            ctx.barrier();
            ctx.fp(1);
            for l in 0..s {
                acc[l] += acc[l + s];
            }
            s /= 2;
        }
        ctx.store("out", &mut out, |l| l as u64, |l| acc[l as usize]);
    });
    vec![k0, k1]
}

/// 5-point stencil on an `n*n` torus (wrap-around is a pow2 mask):
/// five spatially-correlated loads per point, moderate arithmetic.
pub(super) fn stencil2d(n: u32) -> Vec<RecordedKernel> {
    let n = n as u64;
    let m = n - 1;
    let mut dev = Device::new();
    let a = dev.alloc("a", (n * n) as usize, |i| f32_at(7, i));
    let mut b = dev.alloc("b", (n * n) as usize, |_| 0.0f32);
    let (waves, trips) = grid_1d(n * n / 64, 2048);
    let k = record_kernel("stencil2d", waves, |ctx| {
        ctx.for_n(trips, |ctx, t| {
            let e0 = t * 64;
            ctx.salu(4);
            let cv = ctx.load("center", &a, |l| e0 + l as u64);
            let wv = ctx.load("west", &a, |l| {
                let e = e0 + l as u64;
                (e / n) * n + ((e % n + m) & m)
            });
            let ev = ctx.load("east", &a, |l| {
                let e = e0 + l as u64;
                (e / n) * n + ((e % n + 1) & m)
            });
            let nv = ctx.load("north", &a, |l| {
                let e = e0 + l as u64;
                ((e / n + m) & m) * n + e % n
            });
            let sv = ctx.load("south", &a, |l| {
                let e = e0 + l as u64;
                ((e / n + 1) & m) * n + e % n
            });
            ctx.fp(2);
            ctx.store("b", &mut b, |l| e0 + l as u64, |l| {
                let i = l as usize;
                0.25 * (wv[i] + ev[i] + nv[i] + sv[i]) - cv[i]
            });
        });
    });
    vec![k]
}

/// Nonzeros per row in the ELLPACK layout.
const ELL_K: u64 = 8;

/// ELLPACK SpMV over `n` rows, diagonal-at-a-time: the outer loop walks
/// the ELL_K nonzero slots, the inner loop streams this wave's row
/// chunks, accumulating into `y` (read-modify-write).  `cols`, `vals`,
/// and `y` stay coalesced and streaming; `x[cols[..]]` is a seeded
/// random gather — the irregular, latency-bound end of the library.
pub(super) fn spmv_ella(n: u32) -> Vec<RecordedKernel> {
    let n = n as u64;
    let mut dev = Device::new();
    let cols = dev.alloc("cols", (n * ELL_K) as usize, |i| (mix(0xe11 ^ i as u64) % n) as u32);
    let vals = dev.alloc("vals", (n * ELL_K) as usize, |i| f32_at(8, i));
    let x = dev.alloc("x", n as usize, |i| f32_at(9, i));
    let mut y = dev.alloc("y", n as usize, |_| 0.0f32);
    let (waves, trips) = grid_1d(n / 64, 1024);
    let k = record_kernel("spmv_ella", waves, |ctx| {
        ctx.for_n(ELL_K, |ctx, kk| {
            ctx.for_n(trips, |ctx, t| {
                let row0 = t * 64;
                ctx.salu(2);
                let cv = ctx.load("cols", &cols, |l| kk * n + row0 + l as u64);
                let vv = ctx.load("vals", &vals, |l| kk * n + row0 + l as u64);
                let xv = ctx.load("x", &x, |l| cv[l as usize] as u64);
                let yv = ctx.load("y_in", &y, |l| row0 + l as u64);
                ctx.fp(1);
                ctx.store("y_out", &mut y, |l| row0 + l as u64, |l| {
                    let i = l as usize;
                    yv[i] + vv[i] * xv[i]
                });
            });
        });
    });
    vec![k]
}

#[cfg(test)]
mod tests {
    use super::super::{kernels, lower};
    use super::*;
    use crate::sim::isa::{Op, Pattern};

    #[test]
    fn every_library_kernel_lowers_to_a_valid_trace_at_min_and_default() {
        for k in kernels() {
            for size in [k.min_size, k.default_size] {
                let t = lower(k.name, size)
                    .unwrap_or_else(|e| panic!("{}:{size}: {e}", k.name));
                t.validate()
                    .unwrap_or_else(|e| panic!("{}:{size} invalid: {e}", k.name));
                assert_eq!(t.source, format!("exec:{}:{size}", k.name));
                assert_eq!(t.rounds, 1);
                for tk in &t.kernels {
                    let st = tk.stats();
                    assert!(st.loads + st.stores > 0, "{}: no memory ops", k.name);
                    assert!(st.valu > 0, "{}: no arithmetic", k.name);
                }
            }
        }
    }

    #[test]
    fn vectoradd_computes_and_streams() {
        let mut dev = Device::new();
        let n = 4096u64;
        let a = dev.alloc("a", n as usize, |i| f32_at(1, i));
        let b = dev.alloc("b", n as usize, |i| f32_at(2, i));
        let mut c = dev.alloc("c", n as usize, |_| 0.0f32);
        let (_, trips) = grid_1d(n / 64, 4096);
        record_kernel("vectoradd", 1, |ctx| {
            ctx.for_n(trips, |ctx, t| {
                let e0 = t * 64;
                let av = ctx.load("a", &a, |l| e0 + l as u64);
                let bv = ctx.load("b", &b, |l| e0 + l as u64);
                ctx.store("c", &mut c, |l| e0 + l as u64, |l| {
                    av[l as usize] + bv[l as usize]
                });
            });
        });
        // the representative wave computed real sums over its chunks
        for e in 0..(trips * 64) as usize {
            assert_eq!(c.host()[e], a.host()[e] + b.host()[e]);
        }
        // and the lowered trace models streaming loads
        let t = lower("vectoradd", 4096).unwrap();
        let strided_loads = t.kernels[0]
            .records
            .iter()
            .filter(|op| {
                matches!(op, Op::Load { pattern: Pattern::Strided { stride, .. }, .. } if *stride < 2048)
            })
            .count();
        assert_eq!(strided_loads, 2, "a and b loads should classify strided");
    }

    #[test]
    fn matmul_tile_matches_reference() {
        let n = 64u32;
        let nn = n as u64;
        let mut dev = Device::new();
        let a = dev.alloc("a", (nn * nn) as usize, |i| f32_at(3, i));
        let b = dev.alloc("b", (nn * nn) as usize, |i| f32_at(4, i));
        let mut c = dev.alloc("c", (nn * nn) as usize, |_| 0.0f32);
        record_kernel("matmul", 1, |ctx| {
            let mut acc = [0.0f32; 64];
            ctx.for_n(nn / 8, |ctx, kb| {
                for kk in 0..8u64 {
                    let kidx = kb * 8 + kk;
                    let av = ctx.load("a", &a, |l| (l as u64 / 8) * nn + kidx);
                    let bv = ctx.load("b", &b, |l| kidx * nn + (l as u64 % 8));
                    for l in 0..64 {
                        acc[l] += av[l] * bv[l];
                    }
                }
            });
            ctx.store("c", &mut c, |l| (l as u64 / 8) * nn + (l as u64 % 8), |l| {
                acc[l as usize]
            });
        });
        for r in 0..8usize {
            for col in 0..8usize {
                let mut want = 0.0f32;
                for k in 0..n as usize {
                    want += a.host()[r * n as usize + k] * b.host()[k * n as usize + col];
                }
                let got = c.host()[r * n as usize + col];
                assert!(
                    (got - want).abs() <= want.abs() * 1e-4 + 1e-5,
                    "c[{r}][{col}] = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn spmv_gather_classifies_random_and_cols_stay_coalesced() {
        let t = lower("spmv-ella", 16384).unwrap();
        let loads: Vec<&Op> = t.kernels[0]
            .records
            .iter()
            .filter(|op| matches!(op, Op::Load { .. }))
            .collect();
        assert_eq!(loads.len(), 4); // cols, vals, x gather, y read
        let randoms = loads
            .iter()
            .filter(|op| matches!(op, Op::Load { pattern: Pattern::Random { .. }, .. }))
            .count();
        assert_eq!(randoms, 1, "exactly the x gather should classify random");
    }

    #[test]
    fn transpose_write_fans_wide() {
        let t = lower("transpose", 512).unwrap();
        let store_fan = t.kernels[0]
            .records
            .iter()
            .find_map(|op| match op {
                Op::Store { fan, .. } => Some(*fan),
                _ => None,
            })
            .unwrap();
        assert_eq!(store_fan, 16, "column writes should hit the fan cap");
    }

    #[test]
    fn reduce_is_a_two_kernel_workload() {
        let t = lower("reduce", 65536).unwrap();
        assert_eq!(t.kernels.len(), 2);
        assert_eq!(t.kernels[0].name, "reduce_partial");
        assert_eq!(t.kernels[1].name, "reduce_final");
        assert_eq!(t.kernels[1].waves_per_cu, 1);
        let barriers = t.kernels[1].stats().barriers;
        assert_eq!(barriers, 6, "log2(64) tree steps");
    }

    #[test]
    fn nested_loops_stay_within_depth_and_pair_up() {
        let t = lower("spmv-ella", 4096).unwrap();
        let k = &t.kernels[0];
        let begins = k
            .records
            .iter()
            .filter(|op| matches!(op, Op::LoopBegin { .. }))
            .count();
        let ends = k
            .records
            .iter()
            .filter(|op| matches!(op, Op::LoopEnd { .. }))
            .count();
        assert_eq!(begins, 2);
        assert_eq!(ends, 2);
        assert!(k
            .records
            .iter()
            .any(|op| matches!(op, Op::LoopBegin { depth: 0, trips: 8, .. })));
        assert!(k
            .records
            .iter()
            .any(|op| matches!(op, Op::LoopBegin { depth: 1, .. })));
    }
}
