//! Executable kernels as first-class workloads.
//!
//! Kernels here are plain Rust functions over *instrumented device
//! arrays*: [`Device::alloc`] hands out [`DeviceArray`]s with a region
//! id and a virtual base address, and every indexed warp access through
//! [`KernelCtx::load`] / [`KernelCtx::store`] both moves real data and
//! records the access (addresses of all 64 lanes, static site identity,
//! line fan-out).  The harness tracks loop trips ([`KernelCtx::for_n`]),
//! arithmetic ops, and barriers, then lowers the recorded stream
//! through [`crate::trace::capture::capture_recorded`] into the
//! versioned trace format — so `exec:matmul:512` behaves exactly like a
//! `trace:` workload everywhere (simulate, sweep plans, serve), with
//! RunKeys fingerprinting the lowered trace's content hash.
//!
//! Recording model: one representative wavefront executes the kernel.
//! Each [`KernelCtx::for_n`] loop *records* its first iteration and
//! *executes* the rest with event emission suppressed; suppressed
//! iterations still feed first-lane addresses into the per-site stride
//! estimator, so classification (via the shared ingest classifier,
//! [`crate::trace::ingest::classify_pattern`]) reflects the whole
//! access stream, not the first trip.  Addresses are integer-derived,
//! so lowering is bit-deterministic: the same `exec:<kernel>:<size>`
//! spec always produces a byte-identical trace and content hash.

use std::collections::HashMap;

use crate::sim::isa::MAX_LOOP_DEPTH;
use crate::trace::capture::{capture_recorded, MemSite, RecEvent, RecordedKernel};
use crate::trace::format::Trace;
use crate::trace::ingest::fan_from_addrs;

mod kernels;

/// Lanes per wavefront (mirrors the simulator's warp width).
pub const LANES: usize = 64;

/// Virtual-address allocator for a workload's device arrays.  Shared
/// across the kernels of one workload so arrays passed from kernel to
/// kernel keep their region and base.
pub struct Device {
    next_region: u8,
    next_base: u64,
}

impl Device {
    pub fn new() -> Device {
        Device { next_region: 0, next_base: 0x1000_0000 }
    }

    /// Allocate a device array, filling element `i` with `fill(i)`.
    pub fn alloc<T: Copy>(
        &mut self,
        name: &'static str,
        len: usize,
        mut fill: impl FnMut(usize) -> T,
    ) -> DeviceArray<T> {
        assert!(len > 0, "device array '{name}' must be non-empty");
        assert!(self.next_region < 250, "too many device arrays");
        let region = self.next_region;
        self.next_region += 1;
        let base = self.next_base;
        let bytes = (len * std::mem::size_of::<T>()) as u64;
        self.next_base = (base + bytes + 4095) & !4095;
        DeviceArray { name, region, base, data: (0..len).map(&mut fill).collect() }
    }
}

impl Default for Device {
    fn default() -> Self {
        Device::new()
    }
}

/// A device allocation: real host data plus the (region, base) identity
/// the recorder uses to turn element indices into byte addresses.
pub struct DeviceArray<T> {
    name: &'static str,
    region: u8,
    base: u64,
    data: Vec<T>,
}

impl<T: Copy> DeviceArray<T> {
    /// Host view of the array contents (for correctness checks).
    pub fn host(&self) -> &[T] {
        &self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn working_set(&self) -> u32 {
        ((self.data.len() * std::mem::size_of::<T>()) as u64).clamp(64, 256 << 20) as u32
    }
}

/// Per-site address observations, pooled across every execution of the
/// site (recorded and suppressed loop iterations alike).
struct SiteObs {
    region: u8,
    working_set: u32,
    last_first_lane: Option<u64>,
    /// First-lane address deltas between consecutive executions.  The
    /// final stride is their *median*: robust against the one large
    /// jump per enclosing-loop trip that a mean would smear in.
    deltas: Vec<u64>,
    /// Within-warp fallback estimate from the first observation (used
    /// when a site executes only once).
    lane_delta: u32,
}

/// Recorder for one kernel of one workload: owns the event stream, the
/// site table, and the loop bookkeeping.
pub struct KernelCtx {
    total_waves: u64,
    events: Vec<RecEvent>,
    sites: Vec<SiteObs>,
    site_ids: HashMap<(u8, &'static str), u32>,
    /// > 0 while any enclosing loop is past its first iteration:
    /// events are suppressed but addresses still observed.
    suppressed: u32,
    depth: usize,
}

impl KernelCtx {
    fn new(total_waves: u64) -> KernelCtx {
        KernelCtx {
            total_waves: total_waves.max(1),
            events: Vec::new(),
            sites: Vec::new(),
            site_ids: HashMap::new(),
            suppressed: 0,
            depth: 0,
        }
    }

    fn recording(&self) -> bool {
        self.suppressed == 0
    }

    /// Warp-wide load: lane `l` reads element `idx(l)`.  `tag` names
    /// the static access site (one tag per source-level access).
    pub fn load<T: Copy>(
        &mut self,
        tag: &'static str,
        a: &DeviceArray<T>,
        mut idx: impl FnMut(u32) -> u64,
    ) -> [T; LANES] {
        let idxs: [u64; LANES] = std::array::from_fn(|l| idx(l as u32));
        self.observe(tag, a.region, a.base, a.working_set(), std::mem::size_of::<T>(), false, &idxs);
        std::array::from_fn(|l| {
            let i = idxs[l] as usize;
            assert!(i < a.data.len(), "{}[{i}] read out of bounds (len {})", a.name, a.data.len());
            a.data[i]
        })
    }

    /// Warp-wide store: lane `l` writes `val(l)` to element `idx(l)`.
    pub fn store<T: Copy>(
        &mut self,
        tag: &'static str,
        a: &mut DeviceArray<T>,
        mut idx: impl FnMut(u32) -> u64,
        mut val: impl FnMut(u32) -> T,
    ) {
        let idxs: [u64; LANES] = std::array::from_fn(|l| idx(l as u32));
        self.observe(tag, a.region, a.base, a.working_set(), std::mem::size_of::<T>(), true, &idxs);
        for (l, &i) in idxs.iter().enumerate() {
            let i = i as usize;
            assert!(i < a.data.len(), "{}[{i}] write out of bounds (len {})", a.name, a.data.len());
            a.data[i] = val(l as u32);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn observe(
        &mut self,
        tag: &'static str,
        region: u8,
        base: u64,
        working_set: u32,
        elem_size: usize,
        store: bool,
        idxs: &[u64; LANES],
    ) {
        let addrs: Vec<u64> = idxs.iter().map(|&i| base + i * elem_size as u64).collect();
        let key = (region, tag);
        let id = match self.site_ids.get(&key) {
            Some(&i) => i,
            None => {
                let i = self.sites.len() as u32;
                self.sites.push(SiteObs {
                    region,
                    working_set,
                    last_first_lane: None,
                    deltas: Vec::new(),
                    lane_delta: 0,
                });
                self.site_ids.insert(key, i);
                i
            }
        };
        let s = &mut self.sites[id as usize];
        let first = addrs[0];
        if let Some(prev) = s.last_first_lane {
            let d = first.abs_diff(prev);
            if d > 0 {
                s.deltas.push(d);
            }
        }
        s.last_first_lane = Some(first);
        if s.lane_delta == 0 {
            let (mn, mx) = addrs.iter().fold((u64::MAX, 0u64), |(a, b), &x| (a.min(x), b.max(x)));
            if mx > mn {
                s.lane_delta = ((mx - mn) / (LANES as u64 - 1)).clamp(1, 1 << 20) as u32;
            }
        }
        if self.recording() {
            let fan = fan_from_addrs(&addrs);
            self.events.push(RecEvent::Mem { store, site: id, fan });
        }
    }

    /// `count` vector-ALU ops of `cycles` issue cost each.
    pub fn valu(&mut self, cycles: u8, count: u32) {
        if self.recording() {
            for _ in 0..count {
                self.events.push(RecEvent::Alu { vector: true, cycles });
            }
        }
    }

    /// Floating-point vector ops (4-cycle, the ingest FFMA cost).
    pub fn fp(&mut self, count: u32) {
        self.valu(4, count);
    }

    /// Integer/move vector ops (1-cycle).
    pub fn int(&mut self, count: u32) {
        self.valu(1, count);
    }

    /// `count` scalar ops (index arithmetic, control flow).
    pub fn salu(&mut self, count: u32) {
        if self.recording() {
            for _ in 0..count {
                self.events.push(RecEvent::Alu { vector: false, cycles: 1 });
            }
        }
    }

    pub fn barrier(&mut self) {
        if self.recording() {
            self.events.push(RecEvent::Barrier);
        }
    }

    /// A counted loop: records the first iteration (with the executed
    /// trip count), executes all of them.
    pub fn for_n(&mut self, trips: u64, mut body: impl FnMut(&mut KernelCtx, u64)) {
        let trips = trips.max(1);
        assert!(trips <= u16::MAX as u64, "loop trip count {trips} exceeds u16::MAX");
        assert!(self.depth < MAX_LOOP_DEPTH, "loop nesting exceeds depth {MAX_LOOP_DEPTH}");
        if self.recording() {
            self.events.push(RecEvent::LoopBegin { trips: trips as u16 });
        }
        self.depth += 1;
        for i in 0..trips {
            if i == 1 {
                self.suppressed += 1;
            }
            body(self, i);
        }
        if trips > 1 {
            self.suppressed -= 1;
        }
        self.depth -= 1;
        if self.recording() {
            self.events.push(RecEvent::LoopEnd);
        }
    }

    fn finish(self, name: String) -> RecordedKernel {
        let sites = self
            .sites
            .into_iter()
            .map(|mut s| {
                let stride = if !s.deltas.is_empty() {
                    s.deltas.sort_unstable();
                    s.deltas[s.deltas.len() / 2].clamp(4, 4096) as u32
                } else if s.lane_delta > 0 {
                    u64::from(s.lane_delta).clamp(4, 4096) as u32
                } else {
                    64
                };
                MemSite { region: s.region, stride, working_set: s.working_set }
            })
            .collect();
        RecordedKernel { name, total_waves: self.total_waves, events: self.events, sites }
    }
}

/// Run `f` under a fresh recorder and return the recorded kernel.
pub fn record_kernel(
    name: impl Into<String>,
    total_waves: u64,
    f: impl FnOnce(&mut KernelCtx),
) -> RecordedKernel {
    let mut ctx = KernelCtx::new(total_waves);
    f(&mut ctx);
    ctx.finish(name.into())
}

/// One entry in the executable-kernel library.
pub struct ExecKernel {
    pub name: &'static str,
    pub about: &'static str,
    /// What the `<size>` parameter means for this kernel.
    pub size_doc: &'static str,
    pub default_size: u32,
    /// Valid sizes are powers of two in `min_size..=max_size`.
    pub min_size: u32,
    pub max_size: u32,
    build: fn(u32) -> Vec<RecordedKernel>,
}

static KERNELS: [ExecKernel; 6] = [
    ExecKernel {
        name: "vectoradd",
        about: "streaming c[i] = a[i] + b[i]",
        size_doc: "element count",
        default_size: 65536,
        min_size: 4096,
        max_size: 1 << 22,
        build: kernels::vectoradd,
    },
    ExecKernel {
        name: "matmul",
        about: "dense n*n matmul, 8x8 output tile per wave",
        size_doc: "matrix dimension n",
        default_size: 256,
        min_size: 64,
        max_size: 1024,
        build: kernels::matmul,
    },
    ExecKernel {
        name: "transpose",
        about: "naive n*n transpose (coalesced reads, scattered writes)",
        size_doc: "matrix dimension n",
        default_size: 512,
        min_size: 128,
        max_size: 2048,
        build: kernels::transpose,
    },
    ExecKernel {
        name: "reduce",
        about: "two-kernel sum reduction (partials, then a tree fold)",
        size_doc: "element count",
        default_size: 65536,
        min_size: 4096,
        max_size: 1 << 22,
        build: kernels::reduce,
    },
    ExecKernel {
        name: "stencil2d",
        about: "5-point stencil on an n*n torus",
        size_doc: "grid dimension n",
        default_size: 512,
        min_size: 128,
        max_size: 2048,
        build: kernels::stencil2d,
    },
    ExecKernel {
        name: "spmv-ella",
        about: "ELLPACK SpMV, 8 nonzeros/row, random x gather",
        size_doc: "row count",
        default_size: 16384,
        min_size: 4096,
        max_size: 1 << 20,
        build: kernels::spmv_ella,
    },
];

/// The executable-kernel library, in listing order.
pub fn kernels() -> &'static [ExecKernel] {
    &KERNELS
}

/// Look up a kernel by name.
pub fn find(name: &str) -> Option<&'static ExecKernel> {
    KERNELS.iter().find(|k| k.name == name)
}

/// Validate a kernel name + size pair, returning the library entry.
pub fn validate(kernel: &str, size: u32) -> anyhow::Result<&'static ExecKernel> {
    let k = find(kernel).ok_or_else(|| {
        let names: Vec<&str> = KERNELS.iter().map(|k| k.name).collect();
        anyhow::anyhow!(
            "unknown exec kernel '{kernel}' (available: {}; see `pcstall workloads list`)",
            names.join(", ")
        )
    })?;
    anyhow::ensure!(
        size.is_power_of_two() && (k.min_size..=k.max_size).contains(&size),
        "exec:{kernel}: size {size} invalid ({}; power of two in [{}, {}])",
        k.size_doc,
        k.min_size,
        k.max_size
    );
    Ok(k)
}

/// Execute a library kernel at `size` under instrumentation and lower
/// the recording to a validated trace.
pub fn lower(kernel: &str, size: u32) -> anyhow::Result<Trace> {
    let k = validate(kernel, size)?;
    let recorded = (k.build)(size);
    capture_recorded(&format!("{}{}", k.name, size), &format!("exec:{}:{}", k.name, size), &recorded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn library_sizes_are_well_formed() {
        assert!(KERNELS.len() >= 5);
        for k in kernels() {
            assert!(k.min_size.is_power_of_two(), "{}", k.name);
            assert!(k.max_size.is_power_of_two(), "{}", k.name);
            assert!(
                k.default_size.is_power_of_two()
                    && (k.min_size..=k.max_size).contains(&k.default_size),
                "{}: bad default",
                k.name
            );
            validate(k.name, k.default_size).unwrap();
        }
    }

    #[test]
    fn validate_rejects_bad_names_and_sizes() {
        assert!(validate("nope", 256).is_err());
        assert!(validate("matmul", 255).is_err()); // not a power of two
        assert!(validate("matmul", 32).is_err()); // below min
        assert!(validate("matmul", 2048).is_err()); // above max
        assert!(validate("matmul", 256).is_ok());
    }

    #[test]
    fn recorder_suppresses_after_first_iteration_but_observes_strides() {
        let mut dev = Device::new();
        let a = dev.alloc("a", 64 * 8, |i| i as u32);
        let rec = record_kernel("k", 64, |ctx| {
            ctx.for_n(8, |ctx, t| {
                ctx.load("a", &a, |l| t * 64 + l as u64);
                ctx.fp(1);
            });
        });
        // one load + one fp recorded, inside one loop marker pair
        let mems = rec
            .events
            .iter()
            .filter(|e| matches!(e, RecEvent::Mem { .. }))
            .count();
        assert_eq!(mems, 1);
        assert_eq!(rec.events.len(), 4); // LoopBegin, Mem, Alu, LoopEnd
        // 8 executions, first-lane deltas of 256 bytes each
        assert_eq!(rec.sites.len(), 1);
        assert_eq!(rec.sites[0].stride, 256);
    }

    #[test]
    fn device_arrays_get_distinct_regions_and_aligned_bases() {
        let mut dev = Device::new();
        let a = dev.alloc("a", 100, |_| 0u32);
        let b = dev.alloc("b", 100, |_| 0.0f32);
        assert_ne!(a.region, b.region);
        assert_ne!(a.base, b.base);
        assert_eq!(a.base % 4096, 0);
        assert_eq!(b.base % 4096, 0);
        assert_eq!(a.len(), 100);
        assert!(!a.is_empty());
    }
}
