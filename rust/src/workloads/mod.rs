//! Synthetic workload generators reproducing the phase character of the
//! paper's Table II applications.
//!
//! We cannot run the ECP proxy apps / DeepBench / DNNMark HIP binaries on
//! this substrate, so each entry is a seeded generator that reproduces
//! what the paper *reports* about the application: its instruction mix,
//! loop structure, working-set size, inter-wavefront divergence, and the
//! resulting phase behaviour (compute-bound, memory-bound, alternating,
//! thrashing, …).  DESIGN.md §2.2 documents the substitution per app.

pub mod catalog;
pub mod exec;
pub mod source;
pub mod spec;

pub use catalog::{build, names, Workload};
pub use source::{ResolvedWorkload, WorkloadSource};
pub use spec::{KernelSpec, PhaseSpec, WorkloadSpec};
