//! Workload sources: one spec grammar for everything that runs.
//!
//! Everywhere the CLI/harness accepts a workload, it accepts a *spec*:
//!
//! * a catalog name (`comd`, `dgemm`, …) — the Table-II generators;
//! * `trace:<path>` — a recorded/hand-authored/ingested trace file;
//! * `synth:<seed>` — a synthesized trace (see [`crate::trace::synth`]);
//! * `exec:<kernel>[:<size>]` — an executable kernel from the
//!   [`crate::workloads::exec`] library, lowered to a trace on resolve.
//!
//! [`WorkloadSource::parse`] validates the spec, [`WorkloadSource::resolve`]
//! loads it (reading and validating trace files), and
//! [`ResolvedWorkload::lower`] produces the launch list the simulator
//! consumes.  The resolved `id` is what cache fingerprints use: catalog
//! names stay themselves (existing cache entries remain addressable),
//! while trace-driven workloads become `trace:<content-hash>` — the
//! *content*, never the path, so editing a trace file always misses.

use std::path::{Path, PathBuf};

use crate::sim::gpu::KernelLaunch;
use crate::trace::format::Trace;

/// A parsed workload spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkloadSource {
    /// Catalog generator by name.
    Catalog(String),
    /// Trace file on disk (text or binary encoding).
    TraceFile(PathBuf),
    /// Seeded synthesized trace.
    Synth(u64),
    /// Executable library kernel at a size parameter.
    Exec { kernel: String, size: u32 },
}

impl WorkloadSource {
    /// Parse and validate a workload spec string.
    pub fn parse(spec: &str) -> anyhow::Result<WorkloadSource> {
        if let Some(path) = spec.strip_prefix("trace:") {
            anyhow::ensure!(!path.is_empty(), "'trace:' spec needs a file path");
            Ok(WorkloadSource::TraceFile(PathBuf::from(path)))
        } else if let Some(seed) = spec.strip_prefix("synth:") {
            let seed: u64 = seed
                .parse()
                .map_err(|_| anyhow::anyhow!("'synth:' spec needs an integer seed, got '{seed}'"))?;
            Ok(WorkloadSource::Synth(seed))
        } else if let Some(rest) = spec.strip_prefix("exec:") {
            anyhow::ensure!(
                !rest.is_empty(),
                "'exec:' spec needs a kernel name (exec:<kernel>[:<size>]); \
                 see `pcstall workloads list`"
            );
            let (kernel, size) = match rest.split_once(':') {
                Some((k, s)) => {
                    let size: u32 = s.parse().map_err(|_| {
                        anyhow::anyhow!("'exec:{k}:' needs an integer size, got '{s}'")
                    })?;
                    (k, Some(size))
                }
                None => (rest, None),
            };
            // validate at parse time so bad specs fail before any run
            let entry = crate::workloads::exec::find(kernel).ok_or_else(|| {
                let names: Vec<&str> = crate::workloads::exec::kernels()
                    .iter()
                    .map(|k| k.name)
                    .collect();
                anyhow::anyhow!(
                    "unknown exec kernel '{kernel}' (available: {}; see `pcstall workloads list`)",
                    names.join(", ")
                )
            })?;
            let size = size.unwrap_or(entry.default_size);
            crate::workloads::exec::validate(kernel, size)?;
            Ok(WorkloadSource::Exec { kernel: kernel.to_string(), size })
        } else if spec == "synth" {
            // the bare template is only meaningful inside a sweep plan,
            // where the plan-level seed axis instantiates it
            anyhow::bail!(
                "bare 'synth' needs a seed (synth:<seed>); in a sweep plan, a plan-level \
                 seed = [..] axis supplies one per grid point"
            )
        } else if spec == "exec" {
            anyhow::bail!(
                "bare 'exec' needs a kernel (exec:<kernel>[:<size>]); \
                 see `pcstall workloads list`"
            )
        } else if let Some((scheme, _)) = spec.split_once(':') {
            // a scheme-shaped spec with an unknown scheme must not fall
            // through to catalog lookup (typos like 'exce:matmul:512')
            anyhow::bail!(
                "unknown workload spec scheme '{scheme}:' (valid schemes: 'trace:<path>', \
                 'synth:<seed>', 'exec:<kernel>[:<size>]'; see `pcstall workloads list`)"
            )
        } else {
            anyhow::ensure!(
                crate::workloads::names().iter().any(|n| *n == spec),
                "unknown workload '{spec}' (catalog name, 'trace:<path>', 'synth:<seed>', or \
                 'exec:<kernel>[:<size>]'; see `pcstall list` and `pcstall workloads list`)"
            );
            Ok(WorkloadSource::Catalog(spec.to_string()))
        }
    }

    /// Load the source: read + validate trace files, synthesize seeds.
    pub fn resolve(&self) -> anyhow::Result<ResolvedWorkload> {
        match self {
            WorkloadSource::Catalog(name) => Ok(ResolvedWorkload {
                id: name.clone(),
                display: name.clone(),
                kind: Kind::Catalog(name.clone()),
            }),
            WorkloadSource::TraceFile(path) => {
                let trace = Trace::load(Path::new(path))?;
                Ok(ResolvedWorkload::from_trace(trace))
            }
            WorkloadSource::Synth(seed) => {
                let trace = crate::trace::synth::synthesize(*seed);
                Ok(ResolvedWorkload::from_trace(trace))
            }
            WorkloadSource::Exec { kernel, size } => {
                let trace = crate::workloads::exec::lower(kernel, *size)?;
                Ok(ResolvedWorkload::from_trace(trace))
            }
        }
    }
}

/// A source loaded into executable form.
#[derive(Debug, Clone)]
pub struct ResolvedWorkload {
    /// Canonical cache id: the catalog name, or `trace:<content-hash>`.
    pub id: String,
    /// Human-facing label (catalog or trace name).
    pub display: String,
    kind: Kind,
}

#[derive(Debug, Clone)]
enum Kind {
    Catalog(String),
    Trace(Trace),
}

impl ResolvedWorkload {
    fn from_trace(trace: Trace) -> ResolvedWorkload {
        ResolvedWorkload {
            id: format!("trace:{}", trace.content_hash()),
            display: trace.name.clone(),
            kind: Kind::Trace(trace),
        }
    }

    /// Lower to `(launches, rounds)` at workload-length multiplier
    /// `waves` (same knob the catalog generators expose).
    pub fn lower(&self, waves: f64) -> (Vec<KernelLaunch>, u32) {
        match &self.kind {
            Kind::Catalog(name) => {
                let spec = crate::workloads::build(name, waves);
                (spec.launches(), spec.rounds)
            }
            Kind::Trace(trace) => (trace.launches_scaled(waves), trace.rounds),
        }
    }

    /// The underlying trace, when this workload is trace-driven.
    pub fn trace(&self) -> Option<&Trace> {
        match &self.kind {
            Kind::Trace(t) => Some(t),
            Kind::Catalog(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::capture::capture_workload;

    #[test]
    fn catalog_specs_resolve_to_their_name() {
        let r = WorkloadSource::parse("comd").unwrap().resolve().unwrap();
        assert_eq!(r.id, "comd");
        assert_eq!(r.display, "comd");
        let (launches, rounds) = r.lower(0.1);
        assert!(!launches.is_empty());
        assert!(rounds > 0);
    }

    #[test]
    fn unknown_catalog_name_is_an_error_not_a_panic() {
        assert!(WorkloadSource::parse("bogus").is_err());
        assert!(WorkloadSource::parse("trace:").is_err());
        assert!(WorkloadSource::parse("synth:notanumber").is_err());
    }

    #[test]
    fn bare_synth_template_points_at_the_seed_axis() {
        // `synth` without a seed only exists inside sweep plans (the
        // seed = [..] axis instantiates it); everywhere else the error
        // must say so instead of "unknown workload"
        let err = WorkloadSource::parse("synth").unwrap_err().to_string();
        assert!(err.contains("seed = [..]"), "unhelpful error: {err}");
    }

    #[test]
    fn exec_specs_parse_validate_and_default() {
        assert_eq!(
            WorkloadSource::parse("exec:matmul:512").unwrap(),
            WorkloadSource::Exec { kernel: "matmul".into(), size: 512 }
        );
        // omitted size falls back to the library default
        assert_eq!(
            WorkloadSource::parse("exec:matmul").unwrap(),
            WorkloadSource::Exec { kernel: "matmul".into(), size: 256 }
        );
        // bad kernel / size / shape fail at parse time
        assert!(WorkloadSource::parse("exec:").is_err());
        assert!(WorkloadSource::parse("exec").is_err());
        assert!(WorkloadSource::parse("exec:nope:512").is_err());
        assert!(WorkloadSource::parse("exec:matmul:513").is_err());
        assert!(WorkloadSource::parse("exec:matmul:banana").is_err());
    }

    #[test]
    fn exec_specs_resolve_to_content_hash_ids() {
        let a = WorkloadSource::parse("exec:vectoradd:4096").unwrap().resolve().unwrap();
        let b = WorkloadSource::parse("exec:vectoradd:4096").unwrap().resolve().unwrap();
        let c = WorkloadSource::parse("exec:vectoradd:8192").unwrap().resolve().unwrap();
        let d = WorkloadSource::parse("exec:stencil2d:128").unwrap().resolve().unwrap();
        assert_eq!(a.id, b.id, "same spec must give a stable cache id");
        assert_ne!(a.id, c.id, "size change must change the cache id");
        assert_ne!(a.id, d.id, "kernel change must change the cache id");
        assert!(a.id.starts_with("trace:"));
        assert!(a.trace().is_some());
        let (launches, rounds) = a.lower(1.0);
        assert!(!launches.is_empty());
        assert_eq!(rounds, 1);
    }

    #[test]
    fn unknown_schemes_do_not_fall_through_to_catalog_lookup() {
        let err = WorkloadSource::parse("exce:matmul:512").unwrap_err().to_string();
        assert!(
            err.contains("unknown workload spec scheme 'exce:'"),
            "typoed scheme must name itself: {err}"
        );
        assert!(err.contains("exec:<kernel>"), "error must list valid schemes: {err}");
        // catalog names (no colon) still resolve through the catalog arm
        assert!(WorkloadSource::parse("comd").is_ok());
    }

    #[test]
    fn synth_specs_resolve_to_content_hash_ids() {
        let a = WorkloadSource::parse("synth:7").unwrap().resolve().unwrap();
        let b = WorkloadSource::parse("synth:7").unwrap().resolve().unwrap();
        let c = WorkloadSource::parse("synth:8").unwrap().resolve().unwrap();
        assert_eq!(a.id, b.id, "same seed must give a stable cache id");
        assert_ne!(a.id, c.id);
        assert!(a.id.starts_with("trace:"));
    }

    #[test]
    fn trace_file_specs_fingerprint_content_not_path() {
        let dir = std::env::temp_dir().join(format!("pcstall_source_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let t = capture_workload(&crate::workloads::build("dgemm", 0.05));
        let p1 = dir.join("a.trace");
        let p2 = dir.join("b.trace");
        t.save(&p1, false).unwrap();
        t.save(&p2, true).unwrap(); // same content, binary encoding

        let r1 = WorkloadSource::parse(&format!("trace:{}", p1.display()))
            .unwrap()
            .resolve()
            .unwrap();
        let r2 = WorkloadSource::parse(&format!("trace:{}", p2.display()))
            .unwrap()
            .resolve()
            .unwrap();
        assert_eq!(r1.id, r2.id, "content hash must not depend on path/encoding");

        // edit the file -> different id
        let mut edited = t.clone();
        edited.kernels[0].waves_per_cu += 1;
        edited.save(&p1, false).unwrap();
        let r3 = WorkloadSource::parse(&format!("trace:{}", p1.display()))
            .unwrap()
            .resolve()
            .unwrap();
        assert_ne!(r1.id, r3.id, "edited trace must change the cache id");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_trace_file_errors_cleanly() {
        let r = WorkloadSource::parse("trace:/nonexistent/x.trace")
            .unwrap()
            .resolve();
        assert!(r.is_err());
    }

    #[test]
    fn trace_lowering_matches_direct_build() {
        let spec = crate::workloads::build("hacc", 0.1);
        let t = capture_workload(&spec);
        let r = ResolvedWorkload::from_trace(t);
        let (launches, rounds) = r.lower(1.0);
        assert_eq!(rounds, spec.rounds);
        let direct = spec.launches();
        assert_eq!(launches.len(), direct.len());
        for (a, b) in launches.iter().zip(&direct) {
            assert_eq!(a.waves_per_cu, b.waves_per_cu);
            assert_eq!(*a.program, *b.program);
        }
        assert!(r.trace().is_some());
    }
}
