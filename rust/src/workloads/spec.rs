//! Workload specification DSL: phases → kernels → workload.
//!
//! A *phase* is a run of instructions with one character (compute burst,
//! strided stream, random gather…).  A *kernel* is a loop over phases —
//! the loop gives PCSTALL its repetitive PC structure, and phase
//! alternation inside the loop produces the epoch-to-epoch sensitivity
//! variation the paper measures (Figs. 6/7).

use std::sync::Arc;

use crate::sim::gpu::KernelLaunch;
use crate::sim::isa::{Op, Pattern, Program, ProgramBuilder};

/// One phase of a kernel's loop body.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseSpec {
    /// VALU ops emitted in this phase.
    pub valu: u16,
    /// Cycles per VALU op (FMA chains are longer).
    pub valu_cycles: u8,
    /// Vector loads emitted in this phase.
    pub loads: u16,
    /// Vector stores emitted in this phase.
    pub stores: u16,
    /// Access pattern for this phase's memory ops.
    pub pattern: Pattern,
    /// Memory divergence: distinct lines per vector op.
    pub fan: u8,
    /// Emit `s_waitcnt 0` after every `waitcnt_batch` memory ops
    /// (larger batch = more memory-level parallelism).
    pub waitcnt_batch: u8,
}

impl PhaseSpec {
    /// A pure-compute phase.
    pub fn compute(valu: u16, valu_cycles: u8) -> Self {
        PhaseSpec {
            valu,
            valu_cycles,
            loads: 0,
            stores: 0,
            pattern: Pattern::Strided {
                region: 0,
                stride: 64,
                working_set: 1 << 20,
            },
            fan: 1,
            waitcnt_batch: 1,
        }
    }

    /// A memory phase with an explicit pattern.
    pub fn memory(loads: u16, stores: u16, pattern: Pattern, fan: u8, batch: u8) -> Self {
        PhaseSpec {
            valu: 0,
            valu_cycles: 1,
            loads,
            stores,
            pattern,
            fan,
            waitcnt_batch: batch.max(1),
        }
    }

    /// Interleaved compute+memory phase.
    pub fn mixed(
        valu: u16,
        valu_cycles: u8,
        loads: u16,
        pattern: Pattern,
        fan: u8,
        batch: u8,
    ) -> Self {
        PhaseSpec {
            valu,
            valu_cycles,
            loads,
            stores: 0,
            pattern,
            fan,
            waitcnt_batch: batch.max(1),
        }
    }

    /// Static instructions this phase expands to.
    pub fn instr_count(&self) -> usize {
        let mem = (self.loads + self.stores) as usize;
        let waits = mem.div_ceil(self.waitcnt_batch.max(1) as usize);
        self.valu as usize + mem + waits
    }

    fn emit(&self, b: &mut ProgramBuilder) {
        // Interleave: memory ops first in batches (so compute overlaps the
        // outstanding loads), then the remaining compute.
        let mem_total = self.loads + self.stores;
        let mut loads_left = self.loads;
        let mut stores_left = self.stores;
        // Spread compute between batches for realistic overlap.
        let batches = (mem_total as usize).div_ceil(self.waitcnt_batch.max(1) as usize);
        let valu_per_batch = if batches > 0 {
            self.valu as usize / (batches + 1)
        } else {
            self.valu as usize
        };
        let mut valu_left = self.valu as usize;

        for _ in 0..batches {
            for _ in 0..self.waitcnt_batch {
                if loads_left > 0 {
                    b.push(Op::Load {
                        pattern: self.pattern,
                        fan: self.fan,
                    });
                    loads_left -= 1;
                } else if stores_left > 0 {
                    b.push(Op::Store {
                        pattern: self.pattern,
                        fan: self.fan,
                    });
                    stores_left -= 1;
                }
            }
            // overlap compute while the batch is in flight
            for _ in 0..valu_per_batch.min(valu_left) {
                b.push(Op::VAlu {
                    cycles: self.valu_cycles,
                });
            }
            valu_left -= valu_per_batch.min(valu_left);
            b.push(Op::WaitCnt { max: 0 });
        }
        for _ in 0..valu_left {
            b.push(Op::VAlu {
                cycles: self.valu_cycles,
            });
        }
    }
}

/// A kernel: `trips` iterations over the phase sequence.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub name: String,
    pub phases: Vec<PhaseSpec>,
    /// Outer-loop trip count (per wavefront).
    pub trips: u16,
    /// Per-wavefront trip divergence (quickS-style imbalance).
    pub divergence: u16,
    /// Place a workgroup barrier at the end of each iteration (snapc).
    pub barrier: bool,
    /// Waves per CU for this kernel launch.
    pub waves_per_cu: u64,
    /// Per-wavefront warmup loop (mean trips) that desynchronizes phase
    /// positions across wavefronts — real kernels drift apart through
    /// latency jitter within micro-seconds; this models that spread at
    /// dispatch.  0 disables.
    pub stagger: u16,
}

impl KernelSpec {
    /// Lower the spec to an executable [`Program`].
    pub fn lower(&self, kernel_id: u32) -> Program {
        let mut b = ProgramBuilder::new();
        // small prologue (kernel arg setup)
        b.push(Op::SAlu);
        b.push(Op::SAlu);
        if self.stagger > 0 {
            // divergent warmup: trips in [1, 2*stagger], ~10 cycles each
            b.with_loop(3, self.stagger, self.stagger.saturating_sub(1), |b| {
                b.push(Op::VAlu { cycles: 10 });
            });
        }
        let phases = self.phases.clone();
        let barrier = self.barrier;
        b.with_loop(0, self.trips, self.divergence, |b| {
            for p in &phases {
                p.emit(b);
            }
            if barrier {
                b.push(Op::Barrier);
            }
        });
        b.build(kernel_id, self.name.clone())
    }

    pub fn launch(&self, kernel_id: u32) -> KernelLaunch {
        KernelLaunch {
            program: Arc::new(self.lower(kernel_id)),
            waves_per_cu: self.waves_per_cu,
        }
    }

    /// Static instruction footprint (PC-table coverage analysis).
    pub fn static_instrs(&self) -> usize {
        // prologue [+ stagger loop] + LoopBegin + body + LoopEnd
        // [+ barrier] + EndPgm
        let body: usize = self.phases.iter().map(|p| p.instr_count()).sum();
        let stagger = if self.stagger > 0 { 3 } else { 0 };
        2 + stagger + 1 + body + 1 + usize::from(self.barrier) + 1
    }

    /// Dynamic instructions per wavefront (mean trips).
    pub fn dyn_instrs_per_wave(&self) -> usize {
        let body: usize = self.phases.iter().map(|p| p.instr_count()).sum();
        let stagger = if self.stagger > 0 { 1 + 2 * self.stagger as usize } else { 0 };
        2 + stagger + 1 + self.trips as usize * (body + 1 + usize::from(self.barrier)) + 1
    }
}

/// A whole workload: kernels cycled `rounds` times.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub kernels: Vec<KernelSpec>,
    pub rounds: u32,
}

impl WorkloadSpec {
    /// Lower to the launch list the [`crate::Gpu`] consumes.
    pub fn launches(&self) -> Vec<KernelLaunch> {
        self.kernels
            .iter()
            .enumerate()
            .map(|(i, k)| k.launch(i as u32))
            .collect()
    }

    /// Total dynamic instructions per CU (rough completion budget).
    pub fn dyn_instrs_per_cu(&self) -> u64 {
        self.rounds as u64
            * self
                .kernels
                .iter()
                .map(|k| k.dyn_instrs_per_wave() as u64 * k.waves_per_cu)
                .sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::Op;

    fn stream_pattern() -> Pattern {
        Pattern::Strided {
            region: 1,
            stride: 64,
            working_set: 1 << 24,
        }
    }

    #[test]
    fn phase_instr_count_matches_emission() {
        let p = PhaseSpec::mixed(10, 2, 6, stream_pattern(), 1, 3);
        let k = KernelSpec {
            name: "t".into(),
            phases: vec![p],
            trips: 1,
            divergence: 0,
            barrier: false,
            waves_per_cu: 1,
            stagger: 0,
        };
        let prog = k.lower(0);
        assert_eq!(prog.instrs.len(), k.static_instrs());
    }

    #[test]
    fn compute_phase_has_no_memory_ops() {
        let k = KernelSpec {
            name: "c".into(),
            phases: vec![PhaseSpec::compute(8, 4)],
            trips: 2,
            divergence: 0,
            barrier: false,
            waves_per_cu: 1,
            stagger: 0,
        };
        let prog = k.lower(0);
        assert!(prog
            .instrs
            .iter()
            .all(|i| !matches!(i.op, Op::Load { .. } | Op::Store { .. } | Op::WaitCnt { .. })));
    }

    #[test]
    fn memory_phase_batches_waitcnts() {
        let p = PhaseSpec::memory(6, 0, stream_pattern(), 1, 3);
        // 6 loads / batch 3 = 2 waitcnts
        assert_eq!(p.instr_count(), 6 + 2);
    }

    #[test]
    fn barrier_kernel_emits_barrier_per_iteration() {
        let k = KernelSpec {
            name: "b".into(),
            phases: vec![PhaseSpec::compute(2, 1)],
            trips: 3,
            divergence: 0,
            barrier: true,
            waves_per_cu: 4,
            stagger: 0,
        };
        let prog = k.lower(0);
        let barriers = prog
            .instrs
            .iter()
            .filter(|i| matches!(i.op, Op::Barrier))
            .count();
        assert_eq!(barriers, 1); // one static barrier inside the loop
        assert!(prog.validate().is_ok());
    }

    #[test]
    fn lowered_programs_validate() {
        let p = PhaseSpec::mixed(50, 2, 10, stream_pattern(), 2, 5);
        let k = KernelSpec {
            name: "v".into(),
            phases: vec![p, PhaseSpec::compute(20, 1)],
            trips: 10,
            divergence: 4,
            barrier: false,
            waves_per_cu: 8,
            stagger: 0,
        };
        assert!(k.lower(3).validate().is_ok());
    }

    #[test]
    fn dyn_instrs_scale_with_trips() {
        let mut k = KernelSpec {
            name: "d".into(),
            phases: vec![PhaseSpec::compute(10, 1)],
            trips: 5,
            divergence: 0,
            barrier: false,
            waves_per_cu: 2,
            stagger: 0,
        };
        let d5 = k.dyn_instrs_per_wave();
        k.trips = 10;
        let d10 = k.dyn_instrs_per_wave();
        assert!(d10 > d5);
        assert_eq!(d10 - d5, 5 * 11); // 5 extra trips x (10 valu + LoopEnd)
    }
}
