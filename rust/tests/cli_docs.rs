//! Drift gates between the CLI surface and its documentation.
//!
//! `docs/cli.md` is the long-form CLI reference; `pcstall::help::HELP`
//! is what the binary prints.  These tests cross-check them so the
//! reference cannot silently fall behind the binary: every verb and
//! every `--flag` in the help text must appear in `docs/cli.md`, and
//! every `serve.*` registry key must be documented there too.

use std::collections::BTreeSet;
use std::path::PathBuf;

/// Repo-relative documentation file (the crate lives in `rust/`).
fn doc_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join(rel)
}

fn read_doc(rel: &str) -> String {
    let p = doc_path(rel);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("missing documentation file {}: {e}", p.display()))
}

/// Every `--flag` token in `text` (two dashes followed by a lowercase
/// kebab-case word, not preceded by a word character).
fn flag_tokens(text: &str) -> BTreeSet<String> {
    let b = text.as_bytes();
    let mut out = BTreeSet::new();
    let mut i = 0;
    while i + 2 < b.len() {
        let boundary = i == 0 || !(b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'-');
        if boundary && b[i] == b'-' && b[i + 1] == b'-' && b[i + 2].is_ascii_lowercase() {
            let mut j = i + 2;
            while j < b.len() && (b[j].is_ascii_lowercase() || b[j] == b'-') {
                j += 1;
            }
            out.insert(text[i..j].trim_end_matches('-').to_string());
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

#[test]
fn every_help_flag_is_in_the_cli_reference() {
    let help_flags = flag_tokens(pcstall::help::HELP);
    assert!(
        help_flags.contains("--workload") && help_flags.contains("--arrival-trace"),
        "flag scanner is broken: {help_flags:?}"
    );
    let doc = read_doc("docs/cli.md");
    let doc_flags = flag_tokens(&doc);
    let missing: Vec<&String> = help_flags.difference(&doc_flags).collect();
    assert!(
        missing.is_empty(),
        "flags in `pcstall help` but not documented in docs/cli.md: {missing:?}"
    );
}

#[test]
fn every_verb_is_in_help_and_the_cli_reference() {
    let verbs = [
        "simulate", "serve", "run", "experiment", "sweep", "trace", "cache", "obs",
        "list", "config", "table1", "workloads",
    ];
    let doc = read_doc("docs/cli.md");
    for v in verbs {
        let usage = format!("pcstall {v}");
        assert!(
            pcstall::help::HELP.contains(&usage),
            "verb '{v}' missing from pcstall help"
        );
        assert!(doc.contains(&usage), "verb '{v}' missing from docs/cli.md");
    }
}

#[test]
fn every_serve_config_key_is_documented() {
    let doc = read_doc("docs/cli.md");
    let schema = pcstall::config::registry::key_schema();
    let serve_keys: Vec<&str> = schema
        .keys()
        .iter()
        .map(|d| d.path)
        .filter(|p| p.starts_with("serve."))
        .collect();
    assert!(
        serve_keys.len() >= 7,
        "expected the serve.* registry keys, found {serve_keys:?}"
    );
    for key in serve_keys {
        assert!(
            pcstall::help::HELP.contains(key),
            "serve key '{key}' missing from pcstall help"
        );
        assert!(doc.contains(key), "serve key '{key}' missing from docs/cli.md");
    }
}

#[test]
fn every_exec_kernel_is_documented() {
    // the executable-kernel library is CLI surface: `exec:<kernel>` specs
    // and `pcstall workloads list` expose every name, so the help text
    // and the CLI reference must keep up with the registry
    let doc = read_doc("docs/cli.md");
    let kernels = pcstall::workloads::exec::kernels();
    assert!(kernels.len() >= 5, "exec kernel library shrank: {}", kernels.len());
    for k in kernels {
        assert!(
            pcstall::help::HELP.contains(k.name),
            "exec kernel '{}' missing from pcstall help",
            k.name
        );
        assert!(doc.contains(k.name), "exec kernel '{}' missing from docs/cli.md", k.name);
    }
    assert!(
        doc.contains("exec:<kernel>"),
        "docs/cli.md must document the exec:<kernel>[:<size>] spec grammar"
    );
}

#[test]
fn architecture_doc_exists_and_is_linked() {
    let arch = read_doc("ARCHITECTURE.md");
    for section in ["Module map", "Data flow", "Determinism contract", "Result cache"] {
        assert!(arch.contains(section), "ARCHITECTURE.md lost its '{section}' section");
    }
    // the determinism contract names its gating test files
    for gate in ["sim_parallel.rs", "sweep_shard.rs", "serve_mode.rs", "obs_overhead.rs"] {
        assert!(arch.contains(gate), "determinism contract must cite {gate}");
    }
    let readme = read_doc("README.md");
    assert!(readme.contains("ARCHITECTURE.md"), "README must link ARCHITECTURE.md");
    assert!(readme.contains("docs/cli.md"), "README must link docs/cli.md");
}
