//! Sweep-engine integration: a parallel (`--jobs 4`) experiment run must
//! produce byte-identical CSV output to a serial (`--jobs 1`) run, and a
//! repeated invocation against a warm cache must execute zero new
//! simulations (100% cache hits).

use std::path::PathBuf;
use std::sync::Arc;

use pcstall::exec::Engine;
use pcstall::harness::{run_experiment, ExpOptions, Scale};

fn opts(dir: &PathBuf, jobs: usize, engine: Arc<Engine>) -> ExpOptions {
    ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.clone(),
        jobs,
        engine,
        ..Default::default()
    }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_exec_engine_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn parallel_fig14_is_byte_identical_and_second_run_fully_cached() {
    // 1. serial reference, no cache involved at all
    let serial_dir = fresh_dir("serial");
    run_experiment("fig14", &opts(&serial_dir, 1, Arc::new(Engine::no_cache()))).unwrap();
    let serial_csv = std::fs::read(serial_dir.join("fig14.csv")).unwrap();

    // 2. parallel run against a cold cache
    let par_dir = fresh_dir("parallel");
    let cold = Arc::new(Engine::with_cache_dir(par_dir.join("cache")));
    run_experiment("fig14", &opts(&par_dir, 4, cold.clone())).unwrap();
    let parallel_csv = std::fs::read(par_dir.join("fig14.csv")).unwrap();
    assert_eq!(
        serial_csv, parallel_csv,
        "--jobs 4 must emit byte-identical CSV to --jobs 1"
    );
    assert!(cold.executed() > 0, "cold run must execute simulations");
    assert_eq!(cold.cache_stats().hits, 0, "cold cache cannot hit");
    assert_eq!(
        cold.cache_stats().stores,
        cold.executed(),
        "every executed simulation must be persisted"
    );

    // 3. repeat against the warm cache: zero new simulations, 100% hits
    let warm = Arc::new(Engine::with_cache_dir(par_dir.join("cache")));
    run_experiment("fig14", &opts(&par_dir, 4, warm.clone())).unwrap();
    assert_eq!(warm.executed(), 0, "warm cache must not execute anything");
    let stats = warm.cache_stats();
    assert_eq!(stats.misses, 0, "warm cache must not miss: {stats:?}");
    assert_eq!(stats.invalidations, 0, "{stats:?}");
    assert!(stats.hits > 0, "{stats:?}");
    let cached_csv = std::fs::read(par_dir.join("fig14.csv")).unwrap();
    assert_eq!(serial_csv, cached_csv, "cached rerun changed the CSV");

    let _ = std::fs::remove_dir_all(&serial_dir);
    let _ = std::fs::remove_dir_all(&par_dir);
}

#[test]
fn no_cache_engine_still_deduplicates_but_writes_nothing() {
    // fig15 requests the static-1.7 baseline once per design series; the
    // engine must collapse the duplicates even with the cache disabled.
    let dir = fresh_dir("nocache");
    let engine = Arc::new(Engine::no_cache());
    run_experiment("fig15", &opts(&dir, 2, engine.clone())).unwrap();
    assert!(engine.deduped() > 0, "shared baselines were not deduplicated");
    assert_eq!(engine.cache_stats().stores, 0);
    assert!(
        !dir.join("cache").exists(),
        "--no-cache must not create a cache directory"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
