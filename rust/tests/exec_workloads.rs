//! `workloads::exec` integration: executable kernels lower to
//! deterministic traces, fingerprint by content in the result cache,
//! and round-trip through `trace record` / `trace replay` exactly.

use std::path::PathBuf;
use std::sync::Arc;

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::exec::Engine;
use pcstall::harness::evaluation::{run_cells, Cell};
use pcstall::harness::{ExpOptions, Scale};
use pcstall::trace::Trace;
use pcstall::workloads::{exec, WorkloadSource};

fn small_cfg() -> SimConfig {
    let mut c = SimConfig::small();
    c.gpu.n_cu = 4;
    c.gpu.n_wf = 8;
    c
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_exec_wl_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Lowering is a pure function of (kernel, size): re-running the
/// instrumented kernel yields a byte-identical trace text and the same
/// content hash — including when lowerings race on worker threads, the
/// way a `--jobs N` sweep resolves exec cells.
#[test]
fn lowering_is_deterministic_across_reruns_and_threads() {
    for k in exec::kernels() {
        let a = exec::lower(k.name, k.default_size).unwrap();
        let b = exec::lower(k.name, k.default_size).unwrap();
        assert_eq!(a.to_text(), b.to_text(), "{}: rerun text diverged", k.name);
        assert_eq!(a.content_hash(), b.content_hash(), "{}", k.name);
    }
    let reference = exec::lower("stencil2d", 256).unwrap().to_text();
    let texts: Vec<String> = (0..4)
        .map(|_| std::thread::spawn(|| exec::lower("stencil2d", 256).unwrap().to_text()))
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();
    for t in texts {
        assert_eq!(t, reference, "concurrent lowering must be byte-identical");
    }
}

/// Kernel-name and size-parameter changes reach the cache identity:
/// every distinct (kernel, size) resolves to a distinct
/// `trace:<content-hash>` id, and the same spec resolves reproducibly.
#[test]
fn exec_ids_fingerprint_kernel_and_size() {
    let id_of = |spec: &str| {
        let r = WorkloadSource::parse(spec).unwrap().resolve().unwrap();
        assert!(r.id.starts_with("trace:"), "{spec} -> {}", r.id);
        r.id
    };
    assert_eq!(id_of("exec:matmul:128"), id_of("exec:matmul:128"));
    let mut ids: Vec<String> = exec::kernels()
        .iter()
        .flat_map(|k| {
            [k.min_size, k.default_size].map(|s| id_of(&format!("exec:{}:{s}", k.name)))
        })
        .collect();
    let n = ids.len();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "every (kernel, size) must get its own id");
}

/// Exec cells ride the content-addressed result cache: a warm rerun of
/// the same specs executes zero simulations.
#[test]
fn warm_exec_rerun_executes_zero_simulations() {
    let dir = fresh_dir("cache");
    let opts_with = |engine: Arc<Engine>| ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.clone(),
        engine,
        ..Default::default()
    };
    let cells = |opts: &ExpOptions| {
        ["exec:vectoradd:4096", "exec:matmul:64"]
            .iter()
            .map(|spec| {
                Cell::at(
                    opts,
                    spec,
                    Policy::PcStall,
                    Objective::Ed2p,
                    1000.0,
                    RunMode::Epochs(3),
                    1.0,
                )
            })
            .collect::<Vec<_>>()
    };

    let cold = Arc::new(Engine::with_cache_dir(dir.join("cache")));
    let opts = opts_with(cold.clone());
    let results = run_cells(&opts, cells(&opts)).unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(cold.executed(), 2);

    let warm = Arc::new(Engine::with_cache_dir(dir.join("cache")));
    let opts = opts_with(warm.clone());
    let rerun = run_cells(&opts, cells(&opts)).unwrap();
    assert_eq!(warm.executed(), 0, "warm exec rerun must be fully cached");
    for (a, b) in results.iter().zip(&rerun) {
        assert_eq!(a.total_instr, b.total_instr);
        assert_eq!(a.ed2p(), b.ed2p());
    }

    // a size bump is a different workload — it must miss the cache
    let after = Arc::new(Engine::with_cache_dir(dir.join("cache")));
    let opts = opts_with(after.clone());
    let bumped = vec![Cell::at(
        &opts,
        "exec:vectoradd:8192",
        Policy::PcStall,
        Objective::Ed2p,
        1000.0,
        RunMode::Epochs(3),
        1.0,
    )];
    run_cells(&opts, bumped).unwrap();
    assert_eq!(after.executed(), 1, "size change must move the cache key");

    let _ = std::fs::remove_dir_all(&dir);
}

/// `trace record exec:...` then `trace replay` reproduces the direct
/// in-memory run exactly: per-epoch instruction counts and ED²P, through
/// an on-disk round trip of both encodings.
#[test]
fn exec_record_replay_round_trips_exactly() {
    let dir = fresh_dir("replay");
    let trace = exec::lower("stencil2d", 128).unwrap();

    let direct = {
        let mut m = DvfsManager::from_launches(
            small_cfg(),
            trace.launches_scaled(1.0),
            trace.rounds,
            Policy::PcStall,
            Objective::Ed2p,
        );
        m.run(RunMode::Epochs(8), "stencil2d128")
    };

    for (file, binary) in [("stencil.trace", false), ("stencil.tracebin", true)] {
        let path = dir.join(file);
        trace.save(&path, binary).unwrap();
        let loaded = Trace::load(&path).unwrap();
        assert_eq!(loaded.content_hash(), trace.content_hash(), "{file}");
        let mut m = DvfsManager::from_launches(
            small_cfg(),
            loaded.launches_scaled(1.0),
            loaded.rounds,
            Policy::PcStall,
            Objective::Ed2p,
        );
        let replayed = m.run(RunMode::Epochs(8), "stencil2d128");
        assert_eq!(
            direct.records.len(),
            replayed.records.len(),
            "{file}: epoch count diverged"
        );
        for (a, b) in direct.records.iter().zip(&replayed.records) {
            assert_eq!(a.instr, b.instr, "{file}: epoch {} instr diverged", a.epoch);
        }
        assert_eq!(direct.ed2p(), replayed.ed2p(), "{file}: ED²P diverged");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
