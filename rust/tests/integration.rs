//! Cross-module integration tests: simulator + workloads + models +
//! predictors + manager working together, and the experiment harness at
//! smoke scale.

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::models::EstModel;
use pcstall::power::params::{F_STATIC_IDX, N_FREQ};
use pcstall::predictors::OracleSampler;
use pcstall::sim::gpu::Gpu;
use pcstall::workloads;

fn small_cfg() -> SimConfig {
    let mut c = SimConfig::small();
    c.gpu.n_cu = 4;
    c.gpu.n_wf = 8;
    c
}

fn run(policy: Policy, workload: &str, epochs: u64) -> pcstall::stats::RunResult {
    let wl = workloads::build(workload, 0.2);
    let mut m = DvfsManager::new(small_cfg(), &wl, policy, Objective::Ed2p);
    m.run(RunMode::Epochs(epochs), workload)
}

#[test]
fn every_workload_runs_under_every_policy_family() {
    for wl in workloads::names() {
        for p in [
            Policy::Static(F_STATIC_IDX),
            Policy::Reactive(EstModel::Crisp),
            Policy::PcStall,
        ] {
            let r = run(p, wl, 4);
            assert_eq!(r.records.len(), 4, "{wl}/{}", p.name());
            assert!(r.total_instr > 0.0, "{wl}/{} committed nothing", p.name());
            assert!(r.total_energy_j > 0.0);
        }
    }
}

#[test]
fn fixed_work_energy_ordering_static_frequencies() {
    // Same work at higher static frequency must finish faster and burn
    // more energy (cubic power vs linear time).
    let complete = |idx: usize| {
        let wl = workloads::build("hacc", 0.05);
        let mut m = DvfsManager::new(small_cfg(), &wl, Policy::Static(idx), Objective::Ed2p);
        m.run(RunMode::Completion { max_epochs: 50_000 }, "hacc")
    };
    let lo = complete(0);
    let hi = complete(N_FREQ - 1);
    assert!(lo.completed && hi.completed);
    assert!(
        hi.total_time_ns < lo.total_time_ns,
        "2.2GHz not faster: {} vs {}",
        hi.total_time_ns,
        lo.total_time_ns
    );
    assert!(
        hi.total_energy_j > lo.total_energy_j,
        "2.2GHz not more energy: {} vs {}",
        hi.total_energy_j,
        lo.total_energy_j
    );
}

#[test]
fn oracle_tracks_paper_ordering_on_mixed_workload() {
    // Fig. 14 ordering at smoke scale: ORACLE > PCSTALL > reactive.
    // (long enough for the PC table to warm up — the paper's point is
    // that kernels are loopy so the table populates quickly.)
    // average over workloads with contrasting phase behaviour — the
    // reactive gap shows on the variable ones (BwdBN, quickS).
    let avg = |p: Policy| {
        ["comd", "hacc", "BwdBN", "quickS"]
            .iter()
            .map(|wl| run(p, wl, 40).mean_accuracy)
            .sum::<f64>()
            / 4.0
    };
    let oracle = avg(Policy::Oracle);
    let pcstall = avg(Policy::PcStall);
    let stall = avg(Policy::Reactive(EstModel::Stall));
    assert!(oracle > pcstall, "oracle {oracle} !> pcstall {pcstall}");
    assert!(pcstall > stall, "pcstall {pcstall} !> stall {stall}");
}

#[test]
fn oracle_sampling_does_not_perturb_the_run() {
    // Running with interleaved oracle samples must not change the
    // simulated execution (fork-pre-execute is side-effect free).
    let wl = workloads::build("minife", 0.1);
    let mut a = Gpu::new(small_cfg());
    a.load_workload(wl.launches(), wl.rounds);
    let mut b = Gpu::new(small_cfg());
    b.load_workload(wl.launches(), wl.rounds);

    let sampler = OracleSampler::default();
    for _ in 0..5 {
        let _ = sampler.sample(&a); // a gets sampled, b does not
        a.run_epoch();
        b.run_epoch();
    }
    assert_eq!(a.total_instr(), b.total_instr());
    assert_eq!(a.now_ps, b.now_ps);
}

#[test]
fn deterministic_replay_across_managers() {
    let r1 = run(Policy::PcStall, "quickS", 8);
    let r2 = run(Policy::PcStall, "quickS", 8);
    assert_eq!(r1.total_instr, r2.total_instr);
    assert_eq!(r1.total_energy_j, r2.total_energy_j);
    for (a, b) in r1.records.iter().zip(&r2.records) {
        assert_eq!(a.freq_idx, b.freq_idx);
        assert_eq!(a.instr, b.instr);
    }
}

#[test]
fn domain_granularity_reduces_domain_count() {
    let mut cfg = small_cfg();
    cfg.dvfs.cus_per_domain = 2;
    let wl = workloads::build("comd", 0.1);
    let mut m = DvfsManager::new(cfg, &wl, Policy::Oracle, Objective::Ed2p);
    let r = m.run(RunMode::Epochs(3), "comd");
    assert_eq!(r.records[0].freq_idx.len(), 2); // 4 CUs / 2 per domain
}

#[test]
fn energy_bound_objective_limits_slowdown() {
    let complete = |p: Policy, obj: Objective| {
        let wl = workloads::build("hacc", 0.05);
        let mut m = DvfsManager::new(small_cfg(), &wl, p, obj);
        m.run(RunMode::Completion { max_epochs: 50_000 }, "hacc")
    };
    let top = complete(Policy::Static(N_FREQ - 1), Objective::Ed2p);
    let bounded = complete(
        Policy::Oracle,
        Objective::EnergyBound { max_slowdown: 0.05 },
    );
    assert!(bounded.completed);
    // oracle-guided 5% bound: delay within ~15% of max-perf run (model
    // error + epoch quantization allowed), energy not higher.
    assert!(
        bounded.total_time_ns < top.total_time_ns * 1.15,
        "bound violated: {} vs {}",
        bounded.total_time_ns,
        top.total_time_ns
    );
    assert!(bounded.total_energy_j <= top.total_energy_j * 1.02);
}

#[test]
fn harness_smoke_table1_and_fig5() {
    let opts = pcstall::harness::ExpOptions {
        scale: pcstall::harness::Scale::Quick,
        out_dir: std::env::temp_dir().join("pcstall_harness_smoke"),
        ..Default::default()
    };
    pcstall::harness::run_experiment("table1", &opts).unwrap();
    pcstall::harness::run_experiment("fig5", &opts).unwrap();
    assert!(opts.out_dir.join("table1.csv").exists());
    assert!(opts.out_dir.join("fig5.csv").exists());
}

#[test]
fn pjrt_backend_manager_matches_native_manager() {
    // Full-system differential test when the artifact is available.
    let Some(path) = pcstall::runtime::find_artifact(None) else {
        eprintln!("SKIP: no artifact");
        return;
    };
    let backend = match pcstall::runtime::PjrtBackend::load(&path) {
        Ok(b) => Box::new(b),
        Err(e) => panic!("artifact load failed: {e:#}"),
    };
    let wl = workloads::build("comd", 0.2);
    let mut native_mgr = DvfsManager::new(small_cfg(), &wl, Policy::PcStall, Objective::Ed2p);
    let mut pjrt_mgr =
        DvfsManager::with_backend(small_cfg(), &wl, Policy::PcStall, Objective::Ed2p, backend);
    let rn = native_mgr.run(RunMode::Epochs(6), "comd");
    let rp = pjrt_mgr.run(RunMode::Epochs(6), "comd");
    // identical math (f32 parity) -> identical frequency decisions
    for (a, b) in rn.records.iter().zip(&rp.records) {
        assert_eq!(a.freq_idx, b.freq_idx, "decision diverged at epoch {}", a.epoch);
    }
    assert_eq!(rn.total_instr, rp.total_instr);
}

#[test]
fn lulesh_multikernel_cycles_through_all_27() {
    let wl = workloads::build("lulesh", 0.05);
    assert_eq!(wl.kernels.len(), 27);
    let mut gpu = Gpu::new(small_cfg());
    gpu.load_workload(wl.launches(), 1);
    let mut epochs = 0;
    while !gpu.workload_done() && epochs < 50_000 {
        gpu.run_epoch();
        epochs += 1;
    }
    assert!(gpu.workload_done(), "lulesh did not finish in {epochs} epochs");
}
