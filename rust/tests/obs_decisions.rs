//! Decision-trace (obs channel 3) acceptance gates:
//!
//! 1. `decisions.csv` is byte-deterministic across reruns and across
//!    `--jobs 1` vs `--jobs 4`, and the NDJSON sidecar's header object
//!    carries the executed/cached cell accounting.
//! 2. The per-epoch accuracy column reproduces the sweep CSV's
//!    `accuracy` metric (i.e. `RunResult::mean_accuracy`) under the
//!    same warmup exclusion the manager applies.
//! 3. Counterfactual regret is non-negative for oracle-laddered
//!    policies and exactly zero for ORACLE and for policies without a
//!    ladder sample; `chosen == oracle_best` implies zero regret.
//! 4. The emitted sweep CSV is byte-identical with the decision channel
//!    on and off (covered jointly with tests/obs_overhead.rs — the obs
//!    sink carries all three channels).
//! 5. `obs diff` over two identical reruns aligns every row and reports
//!    zero divergence.

use std::path::PathBuf;
use std::sync::Arc;

use pcstall::exec::{Engine, ShardSpec};
use pcstall::harness::sweep::{run_sweep, SweepPlan};
use pcstall::harness::{ExpOptions, Scale};
use pcstall::obs::{diff_decisions, read_decisions, DecisionRow, ObsRecorder};
use pcstall::stats::emit::CsvTable;

/// The manager's prediction-accuracy warmup (first epochs excluded from
/// `mean_accuracy`); must match `ACC_WARMUP` in `dvfs/manager.rs`.
const ACC_WARMUP: u64 = 2;

/// Two oracle-laddered designs (ACCPC pays real regret, ORACLE is the
/// zero-regret fixed point) over a catalog and a synth source, against
/// the default STATIC-1.7 baseline (a no-ladder policy).
const PLAN: &str = r#"
name = "decgate"
epoch_ns = [1000]
cus_per_domain = [1]
workloads = ["comd", "synth:5"]
designs = ["accpc", "oracle"]
epochs = 8
"#;

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_dec_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Run the gate plan once with obs; returns (sweep CSV bytes, run dir).
fn run_once(tag: &str, jobs: usize, obs: bool) -> (Vec<u8>, PathBuf) {
    let dir = fresh_dir(tag);
    let rec = obs.then(|| Arc::new(ObsRecorder::new(dir.join("obs"))));
    let mut engine = Engine::no_cache();
    engine.set_obs(rec.clone());
    let opts = ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.clone(),
        jobs,
        engine: Arc::new(engine),
        obs: rec.clone(),
        ..Default::default()
    };
    let plan = SweepPlan::from_toml(PLAN).unwrap();
    let csv_path = run_sweep(&opts, &plan, ShardSpec::whole()).unwrap();
    let csv = std::fs::read(&csv_path).unwrap();
    if let Some(r) = rec {
        r.write().unwrap();
    }
    (csv, dir)
}

#[test]
fn decisions_csv_is_byte_deterministic_across_jobs_and_reruns() {
    let (csv_a, d1) = run_once("det_serial", 1, true);
    let (csv_b, d2) = run_once("det_par", 4, true);
    let (csv_c, d3) = run_once("det_rerun", 4, true);
    let (csv_off, d4) = run_once("det_off", 4, false);

    let dec = |d: &PathBuf| std::fs::read(d.join("obs").join("decisions.csv")).unwrap();
    let (a, b, c) = (dec(&d1), dec(&d2), dec(&d3));
    assert_eq!(a, b, "decisions.csv must not depend on --jobs");
    assert_eq!(b, c, "decisions.csv must be byte-identical across reruns");

    // decision channel on/off leaves the stats CSV untouched
    assert_eq!(csv_a, csv_off, "obs decisions must not perturb the sweep CSV");
    assert_eq!(csv_a, csv_b);
    assert_eq!(csv_b, csv_c);

    // NDJSON sidecar: header object with cell accounting, then one
    // object per decision row (same count as the CSV's data rows)
    let nd = std::fs::read_to_string(d1.join("obs").join("decisions.ndjson")).unwrap();
    let header = nd.lines().next().unwrap();
    assert!(header.contains("\"channel\":\"decisions\""), "bad header: {header}");
    assert!(header.contains("\"cells_executed\""), "bad header: {header}");
    assert!(header.contains("\"cells_cached\":0"), "no-cache run: {header}");
    let csv_rows = String::from_utf8(a).unwrap().lines().count() - 1;
    assert_eq!(nd.lines().count(), 1 + csv_rows, "header + one object per row");

    for d in [d1, d2, d3, d4] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

/// Decision rows of one cell, in file order.
fn cell_rows<'a>(rows: &'a [DecisionRow], workload: &str, policy: &str) -> Vec<&'a DecisionRow> {
    rows.iter()
        .filter(|r| r.workload == workload && r.policy == policy)
        .collect()
}

#[test]
fn accuracy_column_reproduces_sweep_csv_metric() {
    let (csv, dir) = run_once("acc", 2, true);
    let rows = read_decisions(&dir.join("obs")).unwrap();
    let sweep = CsvTable::parse(&String::from_utf8(csv).unwrap()).unwrap();
    let col = |name: &str| sweep.header.iter().position(|h| h == name).unwrap();
    let (wl_c, design_c, acc_c) = (col("workload"), col("design"), col("accuracy"));

    let mut checked = 0;
    for row in &sweep.rows {
        let (wl, design) = (&row[wl_c], &row[design_c]);
        let cell = cell_rows(&rows, wl, design);
        assert!(!cell.is_empty(), "no decision rows for {wl}/{design}");
        // epoch-level accuracy is repeated on every domain row; average
        // domain-0 rows past the warmup, as the manager does
        let accs: Vec<f64> = cell
            .iter()
            .filter(|r| r.domain == 0 && r.epoch >= ACC_WARMUP && r.accuracy.is_finite())
            .map(|r| r.accuracy)
            .collect();
        let expected: f64 = row[acc_c].parse().unwrap();
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        assert!(
            (mean - expected).abs() < 6e-4, // sweep CSV rounds to 3 decimals
            "{wl}/{design}: decisions-derived mean {mean} vs sweep CSV {expected}"
        );
        checked += 1;
    }
    assert_eq!(checked, 4, "2 workloads x 2 designs");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn regret_invariants_hold_per_policy() {
    let (_, dir) = run_once("regret", 2, true);
    let rows = read_decisions(&dir.join("obs")).unwrap();
    assert!(!rows.is_empty());

    for r in &rows {
        assert!(r.regret >= 0.0, "regret must be non-negative: {r:?}");
        assert!(r.regret.is_finite());
        if r.chosen == r.oracle_best {
            assert_eq!(r.regret, 0.0, "agreeing with the oracle costs nothing: {r:?}");
        }
    }
    // ORACLE is the zero-regret fixed point by definition
    for r in rows.iter().filter(|r| r.policy == "ORACLE") {
        assert_eq!(r.regret, 0.0, "ORACLE row with regret: {r:?}");
        assert_eq!(r.chosen, r.oracle_best);
    }
    // no-ladder policies (the static baseline) report zero regret too
    for r in rows.iter().filter(|r| r.policy.starts_with("STATIC")) {
        assert_eq!(r.regret, 0.0);
        assert!(r.pc.is_none(), "static policy has no PC table");
    }
    // the PC-keyed design resolves epoch-start PCs
    let accpc: Vec<_> = rows.iter().filter(|r| r.policy == "ACCPC").collect();
    assert!(!accpc.is_empty());
    assert!(
        accpc.iter().any(|r| r.pc.is_some()),
        "ACCPC rows must carry modal PCs"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn diff_of_identical_reruns_reports_zero_divergence() {
    let (_, d1) = run_once("diff_a", 2, true);
    let (_, d2) = run_once("diff_b", 2, true);
    let s = diff_decisions(&d1.join("obs"), &d2.join("obs")).unwrap();
    assert!(s.cell_pairs > 0);
    assert_eq!(s.cross_policy_pairs, 0, "same plan on both sides");
    assert!(s.rows_aligned > 0);
    assert_eq!((s.only_a, s.only_b), (0, 0));
    assert_eq!(s.divergent, 0, "identical reruns must not diverge");
    assert_eq!(s.regret_a, s.regret_b);
    let _ = std::fs::remove_dir_all(&d1);
    let _ = std::fs::remove_dir_all(&d2);
}
