//! Observability overhead + determinism gates (the obs subsystem's
//! acceptance contract):
//!
//! 1. With the obs sink installed, every emitted stats CSV is
//!    byte-identical to an uninstrumented run — counters observe, they
//!    never perturb.
//! 2. RunKeys are unchanged by obs: a cache warmed without obs serves a
//!    with-obs rerun entirely from hits (zero misses, zero executions).
//! 3. The counter sidecar (`counters.json`) is byte-deterministic
//!    across reruns and across `--jobs 1` vs `--jobs 4`, and carries
//!    nonzero stall-breakdown + queue-depth content at quick scale.

use std::path::PathBuf;
use std::sync::Arc;

use pcstall::exec::{Engine, ShardSpec};
use pcstall::harness::sweep::{run_sweep, SweepPlan};
use pcstall::harness::{ExpOptions, Scale};
use pcstall::obs::ObsRecorder;
use pcstall::stats::emit::Json;

/// Small but representative: a memory-bound catalog workload and a
/// synth source across two epoch lengths (4 grid points, 8 cells).
const PLAN: &str = r#"
name = "obsgate"
epoch_ns = [1000, 10000]
cus_per_domain = [1]
workloads = ["comd", "synth:5"]
designs = ["pcstall"]
epochs = 8
"#;

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Run the gate plan once; returns (sweep CSV bytes, counters.json
/// bytes when obs was on, run directory).
fn run_once(
    tag: &str,
    jobs: usize,
    obs: bool,
    engine: Engine,
) -> (Vec<u8>, Option<Vec<u8>>, PathBuf) {
    let dir = fresh_dir(tag);
    let rec = obs.then(|| Arc::new(ObsRecorder::new(dir.join("obs"))));
    let mut engine = engine;
    engine.set_obs(rec.clone());
    let opts = ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.clone(),
        jobs,
        engine: Arc::new(engine),
        obs: rec.clone(),
        ..Default::default()
    };
    let plan = SweepPlan::from_toml(PLAN).unwrap();
    let csv_path = run_sweep(&opts, &plan, ShardSpec::whole()).unwrap();
    let csv = std::fs::read(&csv_path).unwrap();
    let counters = rec.map(|r| {
        r.write().unwrap();
        std::fs::read(dir.join("obs").join("counters.json")).unwrap()
    });
    (csv, counters, dir)
}

#[test]
fn stats_csv_is_byte_identical_with_obs_on_and_off() {
    let (off, none, d_off) = run_once("csv_off", 2, false, Engine::no_cache());
    assert!(none.is_none());
    let (on, counters, d_on) = run_once("csv_on", 2, true, Engine::no_cache());
    assert_eq!(
        off, on,
        "obs sink must not perturb the emitted sweep CSV by a single byte"
    );

    // the sidecar carries real content: every executed cell, a nonzero
    // stall breakdown, and populated queue-depth histograms
    let text = String::from_utf8(counters.unwrap()).unwrap();
    let j = Json::parse(&text).unwrap();
    let cells = j.get("cells").and_then(Json::as_arr).unwrap();
    assert_eq!(cells.len(), 8, "4 grid points x (baseline + design)");
    let sum = |key: &str| -> f64 {
        cells
            .iter()
            .map(|c| {
                c.get("counters")
                    .and_then(|k| k.get(key))
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0)
            })
            .sum()
    };
    assert!(sum("epochs") > 0.0);
    assert!(
        sum("stall_waitcnt_ps") + sum("stall_mem_outstanding_ps") + sum("stall_issue_empty_ps")
            > 0.0,
        "stall breakdown must be nonzero at quick scale"
    );
    assert!(sum("l2_accesses") > 0.0);
    let hist_populated = cells.iter().any(|c| {
        c.get("counters")
            .and_then(|k| k.get("l2_queue_depth_hist"))
            .and_then(Json::as_arr)
            .is_some_and(|a| a.iter().any(|v| v.as_f64().unwrap_or(0.0) > 0.0))
    });
    assert!(hist_populated, "queue-depth histograms must be populated");

    let _ = std::fs::remove_dir_all(&d_off);
    let _ = std::fs::remove_dir_all(&d_on);
}

#[test]
fn obs_does_not_perturb_run_keys() {
    // Warm a cache without obs, then rerun with obs against the same
    // cache: every cell must be a hit (identical RunKeys), and the
    // CSVs must still match byte for byte.
    let cache_root = fresh_dir("keys_cache");
    let cache_dir = cache_root.join("cache");
    let (cold, _, d1) = run_once(
        "keys_cold",
        2,
        false,
        Engine::with_cache_dir(cache_dir.clone()),
    );
    let warm_engine = Engine::with_cache_dir(cache_dir.clone());
    let (warm, _, d2) = run_once("keys_warm", 2, true, warm_engine);
    assert_eq!(cold, warm, "cache-served rerun must emit identical bytes");
    // re-probe the cache stats through a fresh engine handle: the warm
    // run's engine was moved, so assert indirectly — a third run with
    // obs off must also be all hits (the cache was not invalidated or
    // forked by the obs run writing different keys)
    let probe = Arc::new(Engine::with_cache_dir(cache_dir.clone()));
    let opts = ExpOptions {
        scale: Scale::Quick,
        out_dir: d2.clone(),
        jobs: 1,
        engine: probe.clone(),
        ..Default::default()
    };
    let plan = SweepPlan::from_toml(PLAN).unwrap();
    run_sweep(&opts, &plan, ShardSpec::whole()).unwrap();
    assert_eq!(probe.executed(), 0, "obs must not change any RunKey");
    assert_eq!(probe.cache_stats().misses, 0);
    assert!(probe.cache_stats().hits > 0);

    let _ = std::fs::remove_dir_all(&cache_root);
    let _ = std::fs::remove_dir_all(&d1);
    let _ = std::fs::remove_dir_all(&d2);
}

#[test]
fn counter_sidecar_is_byte_deterministic_across_jobs_and_reruns() {
    let (_, a, d1) = run_once("det_serial", 1, true, Engine::no_cache());
    let (_, b, d2) = run_once("det_par", 4, true, Engine::no_cache());
    let (_, c, d3) = run_once("det_rerun", 4, true, Engine::no_cache());
    let (a, b, c) = (a.unwrap(), b.unwrap(), c.unwrap());
    assert_eq!(a, b, "counters.json must not depend on --jobs");
    assert_eq!(b, c, "counters.json must be byte-identical across reruns");

    // the other two artifacts exist: a CSV mirror and a Chrome-trace
    // timeline (wall-clock, so only its shape is checked)
    let obs_dir = d1.join("obs");
    let csv = std::fs::read_to_string(obs_dir.join("counters.csv")).unwrap();
    assert!(csv.lines().next().unwrap().starts_with("key_hash,"));
    assert_eq!(csv.lines().count(), 1 + 8, "header + one row per cell");
    let timeline = std::fs::read_to_string(obs_dir.join("timeline.ndjson")).unwrap();
    assert_eq!(timeline.lines().next(), Some("["));
    assert_eq!(timeline.lines().last(), Some("]"));
    assert!(
        timeline.lines().any(|l| l.contains("\"cell.simulate\"")),
        "timeline must carry harness spans: {timeline}"
    );
    assert!(
        timeline.lines().any(|l| l.contains("\"pool.run\"")),
        "timeline must carry pool spans"
    );

    let _ = std::fs::remove_dir_all(&d1);
    let _ = std::fs::remove_dir_all(&d2);
    let _ = std::fs::remove_dir_all(&d3);
}
