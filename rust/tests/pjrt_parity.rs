//! PJRT ⇄ native parity: the AOT artifact (built from the Python
//! constants) and the native Rust mirror must agree on every output.
//! This is the test that pins the power-model constants in
//! `python/compile/params.py` and `rust/src/power/params.rs` together.

use pcstall::dvfs::native::{dvfs_step_native, DvfsStepBackend, StepInputs};
use pcstall::power::params::N_FREQ;
use pcstall::power::PowerParams;
use pcstall::runtime::{find_artifact, PjrtBackend};
use pcstall::util::SplitMix64;

fn artifact_or_skip() -> Option<PjrtBackend> {
    let Some(path) = find_artifact(None) else {
        eprintln!("SKIP: no artifact (run `make artifacts`)");
        return None;
    };
    Some(PjrtBackend::load(&path).expect("artifact must load"))
}

fn random_inputs(seed: u64, n_cu: usize, n_wf: usize) -> StepInputs {
    let mut rng = SplitMix64::new(seed);
    let mut inp = StepInputs::zeros(n_cu, n_wf);
    for i in 0..n_cu * n_wf {
        inp.instr[i] = (rng.next_f64() * 2500.0) as f32;
        inp.t_core_ns[i] = (rng.next_f64() * 1000.0) as f32;
        inp.age_factor[i] = (0.05 + rng.next_f64() * 0.95) as f32;
    }
    for c in 0..n_cu {
        inp.freq_ghz[c] = (1.3 + rng.next_f64() * 0.9) as f32;
        inp.pred_sens[c] = (rng.next_f64() * 40_000.0) as f32;
        inp.pred_i0[c] = (rng.next_f64() * 2_000.0) as f32;
        inp.mask[c] = 1.0;
    }
    inp
}

fn assert_close(name: &str, a: &[f32], b: &[f32], rtol: f32) {
    assert_eq!(a.len(), b.len(), "{name}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.is_infinite() || y.is_infinite() {
            assert_eq!(
                x.is_infinite(),
                y.is_infinite(),
                "{name}[{i}]: inf mismatch {x} vs {y}"
            );
            continue;
        }
        let denom = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() / denom < rtol,
            "{name}[{i}]: {x} vs {y} (rtol {rtol})"
        );
    }
}

#[test]
fn pjrt_matches_native_on_random_inputs() {
    let Some(mut pjrt) = artifact_or_skip() else {
        return;
    };
    let params = PowerParams::default();
    for seed in 0..5 {
        let inp = random_inputs(seed, pjrt.meta.n_cu, pjrt.meta.n_wf);
        let native = dvfs_step_native(&inp, &params);
        let remote = pjrt.step(&inp).expect("pjrt step");
        assert_close("sens_wf", &remote.sens_wf, &native.sens_wf, 1e-4);
        assert_close("sens_cu", &remote.sens_cu, &native.sens_cu, 1e-4);
        assert_close("i0_cu", &remote.i0_cu, &native.i0_cu, 1e-3);
        assert_close("pred_instr", &remote.pred_instr, &native.pred_instr, 1e-4);
        assert_close("power_w", &remote.power_w, &native.power_w, 1e-4);
        assert_close("ednp", &remote.ednp, &native.ednp, 1e-3);
        // argmin may legitimately differ on near-ties; require ednp of the
        // chosen states to be within tolerance instead of index equality.
        for d in 0..pjrt.meta.n_cu {
            let kn = native.best_idx[d] as usize;
            let kp = remote.best_idx[d] as usize;
            let en = native.ednp[d * N_FREQ + kn];
            let ep = native.ednp[d * N_FREQ + kp];
            assert!(
                (en - ep).abs() / en.abs().max(1e-12) < 1e-3,
                "domain {d}: native idx {kn} vs pjrt idx {kp} with ednp {en} vs {ep}"
            );
        }
    }
}

#[test]
fn pjrt_pads_small_simulations() {
    let Some(mut pjrt) = artifact_or_skip() else {
        return;
    };
    // a 4-CU / 8-WF sim on the 64x40 artifact
    let inp = random_inputs(7, 4, 8);
    let native = dvfs_step_native(&inp, &PowerParams::default());
    let remote = pjrt.step(&inp).expect("pjrt step");
    assert_eq!(remote.sens_wf.len(), 4 * 8);
    assert_eq!(remote.best_idx.len(), 4);
    assert_close("sens_wf", &remote.sens_wf, &native.sens_wf, 1e-4);
    assert_close("pred_instr", &remote.pred_instr, &native.pred_instr, 1e-4);
}

#[test]
fn pjrt_masked_domains_select_state_zero() {
    let Some(mut pjrt) = artifact_or_skip() else {
        return;
    };
    let mut inp = random_inputs(11, pjrt.meta.n_cu, pjrt.meta.n_wf);
    for d in 32..pjrt.meta.n_cu {
        inp.mask[d] = 0.0;
        inp.pred_sens[d] = 40_000.0; // would pick top state if unmasked
        inp.pred_i0[d] = 0.0;
    }
    let out = pjrt.step(&inp).expect("pjrt step");
    for d in 32..pjrt.meta.n_cu {
        assert_eq!(out.best_idx[d], 0.0, "masked domain {d} moved");
    }
}
