//! Property-based tests (self-contained driver — proptest is unavailable
//! offline).  Each property runs against many seeded random cases and
//! reports the failing seed for reproduction.

use pcstall::config::SimConfig;
use pcstall::dvfs::native::{dvfs_step_native, StepInputs};
use pcstall::dvfs::objective::Objective;
use pcstall::dvfs::sensitivity::{prediction_accuracy, relative_change, SensEstimate};
use pcstall::power::params::{FREQS_GHZ, N_FREQ};
use pcstall::power::PowerParams;
use pcstall::predictors::PcTables;
use pcstall::sim::gpu::{Gpu, KernelLaunch};
use pcstall::sim::isa::{Op, Pattern, ProgramBuilder};
use pcstall::util::SplitMix64;
use std::sync::Arc;

/// Run `f` for `n` seeded cases; panic with the seed on failure.
fn forall(n: u64, f: impl Fn(&mut SplitMix64)) {
    for seed in 0..n {
        let mut rng = SplitMix64::new(seed * 0x9E37 + 1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random small program that always terminates.
fn random_program(rng: &mut SplitMix64) -> Arc<pcstall::sim::isa::Program> {
    let mut b = ProgramBuilder::new();
    let trips = 1 + rng.next_below(20) as u16;
    let div = rng.next_below(4) as u16;
    let n_ops = 1 + rng.next_below(12);
    let body_seed = rng.next_u64();
    b.with_loop(0, trips, div, |b| {
        let mut rng2 = SplitMix64::new(body_seed);
        let mut outstanding = false;
        for _ in 0..n_ops {
            match rng2.next_below(4) {
                0 => {
                    b.push(Op::VAlu {
                        cycles: 1 + rng2.next_below(6) as u8,
                    });
                }
                1 => {
                    b.push(Op::Load {
                        pattern: Pattern::Random {
                            region: 1,
                            working_set: 1 << 22,
                        },
                        fan: 1 + rng2.next_below(3) as u8,
                    });
                    outstanding = true;
                }
                2 => {
                    b.push(Op::Store {
                        pattern: Pattern::Strided {
                            region: 2,
                            stride: 64,
                            working_set: 1 << 22,
                        },
                        fan: 1,
                    });
                    outstanding = true;
                }
                _ => {
                    if outstanding {
                        b.push(Op::WaitCnt { max: 0 });
                        outstanding = false;
                    } else {
                        b.push(Op::SAlu);
                    }
                }
            }
        }
        if outstanding {
            b.push(Op::WaitCnt { max: 0 });
        }
    });
    Arc::new(b.build(0, "random"))
}

fn random_gpu(rng: &mut SplitMix64) -> Gpu {
    let mut cfg = SimConfig::small();
    cfg.gpu.n_cu = 1 + rng.next_below(4) as usize;
    cfg.gpu.n_wf = 2 + rng.next_below(8) as usize;
    cfg.gpu.issue_width = 1 + rng.next_below(4) as usize;
    let mut gpu = Gpu::new(cfg);
    let program = random_program(rng);
    let waves = 1 + rng.next_below(24);
    gpu.load_workload(
        vec![KernelLaunch {
            program,
            waves_per_cu: waves,
        }],
        1,
    );
    gpu
}

#[test]
fn prop_snapshot_restore_replays_bit_identically() {
    forall(25, |rng| {
        let mut gpu = random_gpu(rng);
        let warm = rng.next_below(3);
        for _ in 0..warm {
            gpu.run_epoch();
        }
        let snap = gpu.snapshot();
        let ob1 = gpu.run_epoch();
        let i1 = gpu.total_instr();
        gpu.restore(&snap);
        let ob2 = gpu.run_epoch();
        let i2 = gpu.total_instr();
        assert_eq!(i1, i2);
        assert_eq!(ob1.wf_instr, ob2.wf_instr);
        assert_eq!(ob1.cu, ob2.cu);
    });
}

#[test]
fn prop_epoch_instruction_accounting_consistent() {
    // CU epoch counters must equal the sum of per-WF commits, and the
    // cumulative counter must equal the sum over epochs.
    forall(25, |rng| {
        let mut gpu = random_gpu(rng);
        let mut cumulative = vec![0u64; gpu.cus.len()];
        for _ in 0..4 {
            let ob = gpu.run_epoch();
            for (c, counters) in ob.cu.iter().enumerate() {
                let wf_sum: f32 = ob.wf_instr[c].iter().sum();
                assert_eq!(
                    counters.instr, wf_sum as u64,
                    "CU {c} epoch counter != WF sum"
                );
                cumulative[c] += counters.instr;
            }
        }
        for (c, cu) in gpu.cus.iter().enumerate() {
            assert_eq!(cu.total_instr, cumulative[c], "cumulative mismatch CU {c}");
        }
    });
}

#[test]
fn prop_epoch_time_accounting_within_bounds() {
    // Per-WF stall + barrier never exceeds the epoch; CU epoch_ps spans
    // the epoch exactly.
    forall(25, |rng| {
        let mut gpu = random_gpu(rng);
        let epoch_ps = pcstall::sim::ns_to_ps(gpu.cfg.dvfs.epoch_ns);
        for _ in 0..3 {
            gpu.run_epoch();
            for cu in &gpu.cus {
                assert_eq!(cu.counters.epoch_ps, epoch_ps);
                assert!(cu.counters.stall_all_ps <= epoch_ps);
                assert!(cu.counters.crit_ps <= epoch_ps);
                assert!(cu.counters.overlap_ps <= epoch_ps);
                for wf in &cu.wavefronts {
                    assert!(
                        wf.ep.stall_ps + wf.ep.barrier_ps <= epoch_ps,
                        "WF blocked longer than the epoch"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_higher_frequency_never_commits_much_fewer_instructions() {
    // Monotonicity (with small tolerance for contention artifacts): same
    // state run at a higher frequency commits at least ~95% as many
    // instructions.
    forall(15, |rng| {
        let gpu = random_gpu(rng);
        let mut lo = gpu.clone();
        let mut hi = gpu.clone();
        lo.set_all_frequencies(FREQS_GHZ[0]);
        hi.set_all_frequencies(FREQS_GHZ[N_FREQ - 1]);
        lo.run_epoch();
        hi.run_epoch();
        let (a, b) = (lo.total_instr() as f64, hi.total_instr() as f64);
        assert!(
            b >= 0.95 * a,
            "higher frequency lost work: lo {a} vs hi {b}"
        );
    });
}

#[test]
fn prop_native_step_outputs_finite_and_consistent() {
    let p = PowerParams::default();
    forall(40, |rng| {
        let n_cu = 1 + rng.next_below(16) as usize;
        let n_wf = 1 + rng.next_below(40) as usize;
        let mut inp = StepInputs::zeros(n_cu, n_wf);
        for v in inp.instr.iter_mut() {
            *v = (rng.next_f64() * 5000.0) as f32;
        }
        for v in inp.t_core_ns.iter_mut() {
            *v = (rng.next_f64() * 1000.0) as f32;
        }
        for v in inp.age_factor.iter_mut() {
            *v = (0.05 + rng.next_f64() * 2.0) as f32;
        }
        for d in 0..n_cu {
            inp.pred_sens[d] = (rng.next_f64() * 50_000.0) as f32;
            inp.pred_i0[d] = (rng.next_f64() * 5_000.0) as f32;
        }
        let out = dvfs_step_native(&inp, &p);
        assert!(out.sens_wf.iter().all(|x| x.is_finite()));
        assert!(out.power_w.iter().all(|x| x.is_finite() && *x > 0.0));
        for d in 0..n_cu {
            // best_idx is a valid argmin of its row
            let k = out.best_idx[d] as usize;
            assert!(k < N_FREQ);
            let row = &out.ednp[d * N_FREQ..(d + 1) * N_FREQ];
            assert!(row.iter().all(|&e| e >= row[k] || !e.is_finite()));
            // predicted instructions are linear in f: check midpoint
            let i0 = out.pred_instr[d * N_FREQ];
            let i9 = out.pred_instr[d * N_FREQ + N_FREQ - 1];
            let mid = out.pred_instr[d * N_FREQ + 4];
            let expect = i0 + (i9 - i0) * (4.0f32 / 9.0);
            assert!(
                (mid - expect).abs() <= 0.01 * expect.abs().max(1.0),
                "grid not linear: {i0} {mid} {i9}"
            );
        }
    });
}

#[test]
fn prop_objective_selection_respects_grid() {
    let p = PowerParams::default();
    forall(60, |rng| {
        let sens = rng.next_f64() * 40_000.0;
        let i0 = rng.next_f64() * 3_000.0;
        for obj in [
            Objective::Edp,
            Objective::Ed2p,
            Objective::EnergyBound { max_slowdown: 0.05 },
        ] {
            let (gi, gp, ge) =
                pcstall::dvfs::native::eval_grid_row(sens, i0, obj.n_exp(), 1000.0, &p);
            let k = obj.select(&gi, &gp, &ge);
            assert!(k < N_FREQ);
            if let Objective::EnergyBound { max_slowdown } = obj {
                assert!(gi[k] + 1e-9 >= gi[N_FREQ - 1] * (1.0 - max_slowdown));
            } else {
                assert!(ge.iter().all(|&e| e >= ge[k]));
            }
        }
    });
}

#[test]
fn prop_pc_table_lookup_returns_latest_update() {
    forall(40, |rng| {
        let mut cfg = pcstall::config::DvfsConfig::default();
        cfg.pc_update_alpha = 1.0;
        let n_cu = 1 + rng.next_below(8) as usize;
        let mut t = PcTables::new(&cfg, n_cu, 8);
        // N random updates; remember the last value per (cu, kernel, bucket)
        let mut expected = std::collections::HashMap::new();
        for _ in 0..200 {
            let cu = rng.next_below(n_cu as u64) as usize;
            let kernel = rng.next_below(4) as u32;
            // bucket-aligned pcs so reconstruction is exact
            let pc = (rng.next_below(100) * 4) as u32;
            let sens = rng.next_f64() * 1000.0;
            t.update_wf(cu, kernel, pc, SensEstimate::new(sens, 1.0));
            expected.insert((cu, kernel, pc), sens);
        }
        for ((cu, kernel, pc), sens) in &expected {
            let e = t.lookup_wf(*cu, 0, *kernel, *pc);
            // aliasing is possible across distinct buckets mapping to the
            // same table slot; verify only when the value matches some
            // expected insert for this table index — at minimum the entry
            // is a value we inserted, never garbage.
            let valid = expected.values().any(|v| (e.sens - v).abs() < 1e-3);
            assert!(valid, "lookup returned un-inserted value {}", e.sens);
            let _ = (cu, kernel, pc, sens);
        }
    });
}

#[test]
fn prop_metric_functions_bounded() {
    forall(200, |rng| {
        let a = (rng.next_f64() - 0.2) * 1e6;
        let b = (rng.next_f64() - 0.2) * 1e6;
        let rc = relative_change(a, b);
        assert!((0.0..=2.0).contains(&rc), "relative_change {rc}");
        let acc = prediction_accuracy(a.abs(), b.abs());
        assert!((0.0..=1.0).contains(&acc), "accuracy {acc}");
    });
}

#[test]
fn prop_workload_determinism_across_builds() {
    // Building the same workload twice yields identical programs.
    forall(8, |rng| {
        let names = pcstall::workloads::names();
        let name = names[rng.next_below(names.len() as u64) as usize];
        let a = pcstall::workloads::build(name, 0.5);
        let b = pcstall::workloads::build(name, 0.5);
        assert_eq!(a.kernels.len(), b.kernels.len());
        for (ka, kb) in a.launches().iter().zip(b.launches().iter()) {
            assert_eq!(ka.program.instrs, kb.program.instrs);
            assert_eq!(ka.waves_per_cu, kb.waves_per_cu);
        }
    });
}
