//! Serve-mode acceptance gates (continuous-traffic DVFS under deadlines):
//!
//! 1. Serve runs are seeded and deterministic: same seed → bit-identical
//!    latency stats and energy, different seed → a different arrival
//!    stream (and different per-launch latencies once launches queue).
//! 2. Percentiles are ordered (p99 ≥ p50 by nearest-rank construction)
//!    and the reported stream accounting is internally consistent.
//! 3. Deadline misses and queueing are monotone in offered load under a
//!    pinned-frequency policy: more launches per µs can only queue more.
//! 4. The `serve.csv` the harness emits is byte-identical across
//!    `--jobs` and `--sim-threads` — execution knobs never leak into
//!    serve artifacts.

use std::path::PathBuf;
use std::sync::Arc;

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::exec::Engine;
use pcstall::harness::serve::{run_serve, ServeSpec};
use pcstall::harness::{ExpOptions, Scale};
use pcstall::stats::{RunResult, ServeStats};
use pcstall::workloads;

/// Small serve config: 4 CUs, a short comd stream, arrivals configured
/// per test.
fn serve_cfg(launches: usize, arrival_rate: f64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.gpu.n_cu = 4;
    cfg.gpu.n_wf = 8;
    cfg.serve.launches = launches;
    cfg.serve.arrival_rate = arrival_rate;
    cfg
}

fn serve_run(cfg: SimConfig, policy: Policy) -> RunResult {
    let spec = workloads::build("comd", 0.02);
    let mut mgr = DvfsManager::from_launches(
        cfg,
        spec.launches(),
        spec.rounds,
        policy,
        Objective::Deadline,
    );
    mgr.run(RunMode::Serve { max_epochs: 50_000 }, "comd")
}

fn stats(r: &RunResult) -> &ServeStats {
    r.serve.as_ref().expect("serve runs carry ServeStats")
}

#[test]
fn serve_runs_are_bit_deterministic_and_seeded() {
    let a = serve_run(serve_cfg(4, 0.05), Policy::PcStall);
    let b = serve_run(serve_cfg(4, 0.05), Policy::PcStall);
    assert_eq!(
        stats(&a).p50_us.to_bits(),
        stats(&b).p50_us.to_bits(),
        "same seed must reproduce per-launch latencies bit-for-bit"
    );
    assert_eq!(stats(&a).p99_us.to_bits(), stats(&b).p99_us.to_bits());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.records.len(), b.records.len());

    let mut other = serve_cfg(4, 0.05);
    other.seed = 9;
    let c = serve_run(other, Policy::PcStall);
    let fingerprint = |r: &RunResult| {
        (
            stats(r).p50_us.to_bits(),
            stats(r).mean_latency_us.to_bits(),
            r.records.len(),
        )
    };
    assert_ne!(
        fingerprint(&a),
        fingerprint(&c),
        "a different master seed must draw a different arrival stream"
    );
}

#[test]
fn percentiles_are_ordered_and_accounting_is_consistent() {
    let r = serve_run(serve_cfg(5, 0.04), Policy::PcStall);
    let s = stats(&r);
    assert_eq!(s.launches, 5, "every offered launch is accounted for");
    assert!(s.completed_launches <= s.launches);
    assert!(s.completed_launches > 0, "the stream must make progress");
    assert!(s.p99_us >= s.p50_us, "p99 {} < p50 {}", s.p99_us, s.p50_us);
    assert!(s.p50_us > 0.0 && s.p50_us.is_finite());
    assert!(s.mean_latency_us > 0.0);
    assert!((0.0..=1.0).contains(&s.deadline_miss_rate));
    assert!(s.throughput_per_ms > 0.0);
    assert!(s.mean_queue_depth > 0.0);
    assert!(r.total_energy_j > 0.0, "energy accrues across the whole horizon");
}

#[test]
fn misses_and_queueing_are_monotone_in_offered_load() {
    // Pinned-frequency policy: service times are load-independent, so
    // raising the offered load can only add queueing delay.
    let run_at = |rate: f64| serve_run(serve_cfg(5, rate), Policy::Static(4));
    let light = run_at(0.004);
    let mid = run_at(0.02);
    let heavy = run_at(0.1);
    let (l, m, h) = (stats(&light), stats(&mid), stats(&heavy));
    assert!(
        l.deadline_miss_rate <= m.deadline_miss_rate + 1e-12
            && m.deadline_miss_rate <= h.deadline_miss_rate + 1e-12,
        "miss rate must be monotone in load: {} {} {}",
        l.deadline_miss_rate,
        m.deadline_miss_rate,
        h.deadline_miss_rate
    );
    assert!(
        h.mean_queue_depth > l.mean_queue_depth,
        "25x the offered load must congest the queue: light {} heavy {}",
        l.mean_queue_depth,
        h.mean_queue_depth
    );
    assert!(
        h.mean_latency_us >= l.mean_latency_us,
        "queueing delay only adds latency: light {} heavy {}",
        l.mean_latency_us,
        h.mean_latency_us
    );
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_servegate_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn serve_csv_is_byte_identical_across_jobs_and_sim_threads() {
    let run_with = |tag: &str, jobs: usize, sim_threads: Option<usize>| {
        let dir = fresh_dir(tag);
        let opts = ExpOptions {
            scale: Scale::Quick,
            out_dir: dir.clone(),
            jobs,
            engine: Arc::new(Engine::no_cache()),
            sim_threads,
            ..Default::default()
        };
        let mut cfg = opts.base_cfg();
        cfg.serve.launches = 3;
        cfg.serve.arrival_rate = 0.05;
        let spec = ServeSpec {
            workload: "comd".into(),
            policies: vec![
                Policy::parse("crisp").unwrap(),
                Policy::PcStall,
            ],
            objective: Objective::Deadline,
            arrival_gaps_us: None,
        };
        let path = run_serve(&opts, cfg, &spec).unwrap();
        let bytes = std::fs::read(path).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        bytes
    };

    let serial = run_with("serial", 1, Some(1));
    let wide_jobs = run_with("jobs", 4, Some(1));
    let wide_sim = run_with("sim", 1, Some(4));
    assert!(!serial.is_empty());
    assert_eq!(serial, wide_jobs, "serve.csv must not depend on --jobs");
    assert_eq!(serial, wide_sim, "serve.csv must not depend on --sim-threads");

    let text = String::from_utf8(serial).unwrap();
    let header = text.lines().next().unwrap();
    for col in ["p50_us", "p99_us", "miss_rate", "energy_j"] {
        assert!(header.contains(col), "serve.csv header lost '{col}': {header}");
    }
    assert_eq!(text.lines().count(), 3, "header + one row per policy");
}
