//! Deterministic intra-simulation parallelism acceptance gates:
//!
//! 1. A sweep's stats CSV and both byte-deterministic obs sidecars
//!    (`counters.json`, `decisions.csv`) are byte-identical for
//!    `--sim-threads 1` vs `--sim-threads 4` vs a rerun — CU threads
//!    may only move wall-clock, never results.
//! 2. The oracle policy's snapshot → pre-execute → restore loop is
//!    bit-identical under threading (`f64::to_bits` on ED²P / energy /
//!    instructions), including `--sim-threads 0` (machine-wide).
//! 3. `gpu.sim_threads` is excluded from run identity: a cache warmed
//!    at one thread count serves a rerun at another with zero
//!    executions and zero cache misses.

use std::path::PathBuf;
use std::sync::Arc;

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::exec::{Engine, ShardSpec};
use pcstall::harness::sweep::{run_sweep, SweepPlan};
use pcstall::harness::{ExpOptions, Scale};
use pcstall::obs::ObsRecorder;
use pcstall::workloads;

/// A mixed catalog + synth population over a reactive and an
/// oracle-laddered design: exercises CU stepping, the quantum barrier,
/// snapshot/restore pre-execution, and the decision trace at once.
const PLAN: &str = r#"
name = "pargate"
epoch_ns = [1000]
cus_per_domain = [1]
workloads = ["comd", "synth:5"]
designs = ["pcstall", "oracle"]
epochs = 8
"#;

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_par_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Run the gate plan with obs on and an explicit `--sim-threads`;
/// returns (sweep CSV bytes, run dir).
fn run_once(tag: &str, sim_threads: usize) -> (Vec<u8>, PathBuf) {
    let dir = fresh_dir(tag);
    let rec = Arc::new(ObsRecorder::new(dir.join("obs")));
    let mut engine = Engine::no_cache();
    engine.set_obs(Some(rec.clone()));
    let opts = ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.clone(),
        jobs: 2,
        engine: Arc::new(engine),
        obs: Some(rec.clone()),
        sim_threads: Some(sim_threads),
        ..Default::default()
    };
    let plan = SweepPlan::from_toml(PLAN).unwrap();
    let csv_path = run_sweep(&opts, &plan, ShardSpec::whole()).unwrap();
    let csv = std::fs::read(&csv_path).unwrap();
    rec.write().unwrap();
    (csv, dir)
}

#[test]
fn thread_count_leaves_every_artifact_byte_identical() {
    let (csv_1, d1) = run_once("t1", 1);
    let (csv_4, d4) = run_once("t4", 4);
    let (csv_r, dr) = run_once("t4_rerun", 4);

    assert_eq!(csv_1, csv_4, "sweep CSV must not depend on --sim-threads");
    assert_eq!(csv_4, csv_r, "sweep CSV must be byte-identical across reruns");

    for sidecar in ["counters.json", "decisions.csv"] {
        let read = |d: &PathBuf| std::fs::read(d.join("obs").join(sidecar)).unwrap();
        let (a, b, c) = (read(&d1), read(&d4), read(&dr));
        assert!(!a.is_empty(), "{sidecar} missing");
        assert_eq!(a, b, "{sidecar} must not depend on --sim-threads");
        assert_eq!(b, c, "{sidecar} must be byte-identical across reruns");
    }

    for d in [d1, d4, dr] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

/// Bit patterns of the headline metrics of one oracle run.
fn oracle_bits(sim_threads: usize) -> (u64, u64, u64) {
    let mut cfg = SimConfig::default();
    cfg.gpu.n_cu = 4;
    cfg.gpu.n_wf = 8;
    cfg.gpu.sim_threads = sim_threads;
    let spec = workloads::build("comd", 0.05);
    let mut mgr = DvfsManager::from_launches(
        cfg,
        spec.launches(),
        spec.rounds,
        Policy::parse("oracle").unwrap(),
        Objective::parse("ed2p").unwrap(),
    );
    let r = mgr.run(RunMode::Epochs(8), "comd");
    (
        r.ed2p().to_bits(),
        r.total_energy_j.to_bits(),
        r.total_instr.to_bits(),
    )
}

#[test]
fn oracle_snapshot_restore_is_bit_identical_under_threading() {
    let serial = oracle_bits(1);
    assert_eq!(serial, oracle_bits(4), "pinned width must match serial");
    assert_eq!(serial, oracle_bits(0), "machine-wide must match serial");
}

#[test]
fn cache_warmed_serial_serves_threaded_rerun() {
    let dir = fresh_dir("warm");
    let plan = SweepPlan::from_toml(PLAN).unwrap();
    let run_with = |tag: &str, sim_threads: usize| {
        let engine = Arc::new(Engine::with_cache_dir(dir.join("cache")));
        let opts = ExpOptions {
            scale: Scale::Quick,
            out_dir: dir.join(tag),
            jobs: 2,
            engine: engine.clone(),
            sim_threads: Some(sim_threads),
            ..Default::default()
        };
        let csv_path = run_sweep(&opts, &plan, ShardSpec::whole()).unwrap();
        (engine, std::fs::read(csv_path).unwrap())
    };

    let (cold, csv_cold) = run_with("cold", 1);
    assert!(cold.executed() > 0, "cold run must execute");

    // a different thread count must hash to the same RunKeys
    let (warm, csv_warm) = run_with("warm", 4);
    assert_eq!(warm.executed(), 0, "warm cache must not execute");
    let st = warm.cache_stats();
    assert_eq!(st.misses, 0, "sim_threads must not perturb run identity");
    assert!(st.hits > 0);
    assert_eq!(csv_cold, csv_warm, "cache-served rerun must emit identical CSV");

    let _ = std::fs::remove_dir_all(&dir);
}
