//! Sweep-plan sharding integration: a grid split `--shard 0/3..2/3` and
//! merged must reproduce the unsharded CSV byte-for-byte, shards must be
//! cache-compatible (a warm rerun of any shard executes zero
//! simulations), and an incomplete part set must refuse to merge.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use pcstall::exec::{Engine, ShardSpec};
use pcstall::harness::sweep::{merge_dir, run_sweep, SweepPlan};
use pcstall::harness::{ExpOptions, Scale};
use pcstall::stats::plot::Band;

/// Tiny but genuinely multi-dimensional: 2 epoch lengths × 2 domain
/// granularities × 2 workload sources (catalog + synth) × 1 design.
const TINY_PLAN: &str = r#"
name = "tiny"
epoch_ns = [1000, 10000]
cus_per_domain = [1, 2]
workloads = ["comd", "synth:5"]
designs = ["pcstall"]
epochs = 12
"#;

fn opts(dir: &Path, engine: Arc<Engine>) -> ExpOptions {
    ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.to_path_buf(),
        jobs: 2,
        engine,
        ..Default::default()
    }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_sweep_shard_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn sharded_merge_is_byte_identical_and_warm_shard_executes_nothing() {
    let plan = SweepPlan::from_toml(TINY_PLAN).unwrap();

    // 1. unsharded reference, no cache involved at all
    let ref_dir = fresh_dir("unsharded");
    run_sweep(
        &opts(&ref_dir, Arc::new(Engine::no_cache())),
        &plan,
        ShardSpec::whole(),
    )
    .unwrap();
    let reference = std::fs::read(ref_dir.join("sweep_tiny.csv")).unwrap();
    let ref_rows = reference.iter().filter(|&&b| b == b'\n').count() - 1;
    assert_eq!(ref_rows, 8, "2 epochs x 2 grans x 2 workloads x 1 design");
    // golden back-compat: a plan without an [axis] table must keep the
    // closed-axis-set era's exact CSV schema
    let header = std::str::from_utf8(&reference).unwrap().lines().next().unwrap();
    assert_eq!(
        header,
        "epoch_us,cus_per_domain,workload,seed,design,objective,\
         improvement_pct,norm,energy_j,time_ms,accuracy",
        "legacy sweep CSV schema drifted"
    );

    // 2. three shards into one directory, sharing one result cache
    let shard_dir = fresh_dir("sharded");
    let cache_dir = shard_dir.join("cache");
    let mut owned_total = 0u64;
    for index in 0..3usize {
        let engine = Arc::new(Engine::with_cache_dir(cache_dir.clone()));
        run_sweep(
            &opts(&shard_dir, engine.clone()),
            &plan,
            ShardSpec { index, count: 3 },
        )
        .unwrap();
        owned_total += engine.executed() + engine.cache_stats().hits;
        let part = shard_dir.join(format!("sweep_tiny.part{index}of3.csv"));
        assert!(part.exists(), "missing {}", part.display());
        // every sharded run leaves a meta sidecar for the merge summary
        let meta = shard_dir.join(format!("sweep_tiny.part{index}of3.meta.json"));
        let text = std::fs::read_to_string(&meta)
            .unwrap_or_else(|e| panic!("missing {}: {e}", meta.display()));
        for key in ["\"part\"", "\"of\"", "\"rows\"", "\"cache_hits\"", "\"executed\""] {
            assert!(text.contains(key), "{key} missing from {text}");
        }
    }
    // every unique cell ran (or hit) somewhere; shared baselines may be
    // computed by one shard and hit by another, never more than once each
    assert!(owned_total > 0);

    // 3. merge reproduces the unsharded CSV byte-for-byte
    let written = merge_dir(&shard_dir).unwrap();
    assert_eq!(written, vec![shard_dir.join("sweep_tiny.csv")]);
    let merged = std::fs::read(&written[0]).unwrap();
    assert_eq!(
        merged, reference,
        "merged shard output must be byte-identical to the unsharded run"
    );

    // 4. a warm-cache rerun of any shard executes zero simulations
    let part1 = shard_dir.join("sweep_tiny.part1of3.csv");
    let owned_rows = std::fs::read_to_string(&part1).unwrap().lines().count() - 1;
    let warm = Arc::new(Engine::with_cache_dir(cache_dir.clone()));
    run_sweep(
        &opts(&shard_dir, warm.clone()),
        &plan,
        ShardSpec { index: 1, count: 3 },
    )
    .unwrap();
    assert_eq!(warm.executed(), 0, "warm shard rerun must not simulate");
    let stats = warm.cache_stats();
    assert_eq!(stats.misses, 0, "{stats:?}");
    if owned_rows > 0 {
        assert!(stats.hits > 0, "{stats:?}");
    }

    // 5. an incomplete part set refuses to merge
    std::fs::remove_file(shard_dir.join("sweep_tiny.part2of3.csv")).unwrap();
    let err = merge_dir(&shard_dir).unwrap_err().to_string();
    assert!(err.contains("missing"), "unhelpful error: {err}");

    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&shard_dir);
}

/// A seed-population plan: 2 epoch lengths × 3 synth seeds, with a
/// `[set]` override riding along (the satellite contract: the seed axis
/// and `[set]` compose).
const SEED_PLAN: &str = r#"
name = "pop"
epoch_ns = [1000, 10000]
cus_per_domain = [1]
workloads = ["synth"]
seed = [1, 2, 3]
designs = ["pcstall"]
epochs = 6
[set]
gpu.n_wf = 4
"#;

#[test]
fn seed_axis_shard_union_is_byte_identical_to_unsharded_csv() {
    let plan = SweepPlan::from_toml(SEED_PLAN).unwrap();

    // unsharded reference, no cache
    let ref_dir = fresh_dir("seed_unsharded");
    run_sweep(
        &opts(&ref_dir, Arc::new(Engine::no_cache())),
        &plan,
        ShardSpec::whole(),
    )
    .unwrap();
    let reference = std::fs::read_to_string(ref_dir.join("sweep_pop.csv")).unwrap();
    let rows: Vec<&str> = reference.lines().skip(1).collect();
    assert_eq!(rows.len(), 6, "2 epochs x 3 seeds x 1 design");
    // the seed coordinate is a first-class CSV column
    let header = reference.lines().next().unwrap();
    let seed_col = header
        .split(',')
        .position(|h| h == "seed")
        .expect("seed column in sweep CSV header");
    let mut seeds: Vec<&str> = rows
        .iter()
        .map(|r| r.split(',').nth(seed_col).unwrap())
        .collect();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds, vec!["1", "2", "3"]);
    assert!(
        rows.iter().all(|r| r.contains("synth:")),
        "every population row runs a synthesized source"
    );

    // 2-way shard into one directory, shared cache, then merge
    let shard_dir = fresh_dir("seed_sharded");
    let cache_dir = shard_dir.join("cache");
    for index in 0..2usize {
        run_sweep(
            &opts(&shard_dir, Arc::new(Engine::with_cache_dir(cache_dir.clone()))),
            &plan,
            ShardSpec { index, count: 2 },
        )
        .unwrap();
    }
    let written = merge_dir(&shard_dir).unwrap();
    assert_eq!(written, vec![shard_dir.join("sweep_pop.csv")]);
    let merged = std::fs::read_to_string(&written[0]).unwrap();
    assert_eq!(
        merged, reference,
        "seed-axis shard union must be byte-identical to the unsharded CSV"
    );

    // end-to-end figure trail: plotting the merged CSV twice emits
    // byte-identical script pairs (the CI determinism gate)
    let plot_a = shard_dir.join("plot_a");
    let plot_b = shard_dir.join("plot_b");
    let (gp_a, py_a) =
        pcstall::stats::plot::emit_plot_scripts(&written[0], "accuracy", Band::MinMax, Some(&plot_a))
            .unwrap();
    let (gp_b, py_b) =
        pcstall::stats::plot::emit_plot_scripts(&written[0], "accuracy", Band::MinMax, Some(&plot_b))
            .unwrap();
    assert_eq!(
        std::fs::read(&gp_a).unwrap(),
        std::fs::read(&gp_b).unwrap(),
        "gnuplot script must be deterministic"
    );
    assert_eq!(
        std::fs::read(&py_a).unwrap(),
        std::fs::read(&py_b).unwrap(),
        "matplotlib script must be deterministic"
    );
    let gp = std::fs::read_to_string(&gp_a).unwrap();
    assert!(
        gp.contains("min-max over seed, n=3"),
        "band must aggregate the 3-seed population: {gp}"
    );

    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&shard_dir);
}

/// A config-axis plan: a `dvfs.transition_ns` grid dimension riding the
/// epoch axis (the acceptance path of the generic-axis redesign).
const AXIS_PLAN: &str = r#"
name = "lat"
epoch_ns = [1000, 10000]
cus_per_domain = [1]
workloads = ["comd"]
designs = ["pcstall"]
epochs = 6
[axis]
"dvfs.transition_ns" = [5, 1000]
"#;

#[test]
fn config_axis_shard_union_is_byte_identical_and_plots_the_axis_as_x() {
    let plan = SweepPlan::from_toml(AXIS_PLAN).unwrap();

    // unsharded reference, no cache
    let ref_dir = fresh_dir("axis_unsharded");
    run_sweep(
        &opts(&ref_dir, Arc::new(Engine::no_cache())),
        &plan,
        ShardSpec::whole(),
    )
    .unwrap();
    let reference = std::fs::read_to_string(ref_dir.join("sweep_lat.csv")).unwrap();
    let header = reference.lines().next().unwrap();
    // the config axis is a first-class CSV column, named by its key,
    // spliced between the coordinate and metric columns
    assert_eq!(
        header,
        "epoch_us,cus_per_domain,workload,seed,design,objective,\
         dvfs.transition_ns,improvement_pct,norm,energy_j,time_ms,accuracy"
    );
    let rows: Vec<&str> = reference.lines().skip(1).collect();
    assert_eq!(rows.len(), 4, "2 transition latencies x 2 epochs");
    let lat_col = header.split(',').position(|h| h == "dvfs.transition_ns").unwrap();
    let mut lats: Vec<&str> = rows
        .iter()
        .map(|r| r.split(',').nth(lat_col).unwrap())
        .collect();
    lats.sort_unstable();
    lats.dedup();
    assert_eq!(lats, vec!["1000.0", "5.0"], "canonical axis coordinates");

    // 2-way shard into one directory, shared cache, then merge
    let shard_dir = fresh_dir("axis_sharded");
    let cache_dir = shard_dir.join("cache");
    for index in 0..2usize {
        run_sweep(
            &opts(&shard_dir, Arc::new(Engine::with_cache_dir(cache_dir.clone()))),
            &plan,
            ShardSpec { index, count: 2 },
        )
        .unwrap();
    }
    let written = merge_dir(&shard_dir).unwrap();
    assert_eq!(written, vec![shard_dir.join("sweep_lat.csv")]);
    let merged = std::fs::read_to_string(&written[0]).unwrap();
    assert_eq!(
        merged, reference,
        "config-axis shard union must be byte-identical to the unsharded CSV"
    );

    // `sweep plot` infers the config axis as x (it ties the epoch axis
    // at 2 distinct values; declared axes win ties), deterministically
    let plot_a = shard_dir.join("plot_a");
    let plot_b = shard_dir.join("plot_b");
    let (gp_a, _) = pcstall::stats::plot::emit_plot_scripts(
        &written[0],
        "improvement_pct",
        Band::MinMax,
        Some(&plot_a),
    )
    .unwrap();
    let (gp_b, _) = pcstall::stats::plot::emit_plot_scripts(
        &written[0],
        "improvement_pct",
        Band::MinMax,
        Some(&plot_b),
    )
    .unwrap();
    assert_eq!(
        std::fs::read(&gp_a).unwrap(),
        std::fs::read(&gp_b).unwrap(),
        "gnuplot script must be deterministic"
    );
    let gp = std::fs::read_to_string(&gp_a).unwrap();
    assert!(
        gp.contains("set xlabel \"dvfs.transition_ns\""),
        "config axis must be the inferred x axis: {gp}"
    );

    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&shard_dir);
}

#[test]
fn shard_of_one_equals_unsharded_rows() {
    // --shard 0/1 is the whole grid: same rows, same final CSV name.
    let plan = SweepPlan::from_toml(TINY_PLAN).unwrap();
    let dir = fresh_dir("whole");
    run_sweep(
        &opts(&dir, Arc::new(Engine::no_cache())),
        &plan,
        ShardSpec::parse("0/1").unwrap(),
    )
    .unwrap();
    assert!(dir.join("sweep_tiny.csv").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
