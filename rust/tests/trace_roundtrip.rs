//! Trace subsystem integration: record → replay determinism against the
//! direct simulation, cache-key stability of trace workloads, and
//! corrupt-file behaviour (clear errors, never panics).

use std::path::PathBuf;
use std::sync::Arc;

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::exec::Engine;
use pcstall::harness::evaluation::{run_cells, Cell};
use pcstall::harness::{ExpOptions, Scale};
use pcstall::stats::RunResult;
use pcstall::trace::{capture_workload, synthesize, Trace};
use pcstall::workloads;

fn small_cfg() -> SimConfig {
    let mut c = SimConfig::small();
    c.gpu.n_cu = 4;
    c.gpu.n_wf = 8;
    c
}

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcstall_trace_rt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn assert_identical_runs(direct: &RunResult, replayed: &RunResult, what: &str) {
    assert_eq!(
        direct.records.len(),
        replayed.records.len(),
        "{what}: epoch count diverged"
    );
    for (a, b) in direct.records.iter().zip(&replayed.records) {
        assert_eq!(a.instr, b.instr, "{what}: epoch {} instr diverged", a.epoch);
        assert_eq!(a.freq_idx, b.freq_idx, "{what}: epoch {} freqs diverged", a.epoch);
    }
    assert_eq!(direct.total_instr, replayed.total_instr, "{what}");
    assert_eq!(direct.total_energy_j, replayed.total_energy_j, "{what}");
    assert_eq!(direct.total_time_ns, replayed.total_time_ns, "{what}");
    assert_eq!(direct.ed2p(), replayed.ed2p(), "{what}: ED²P diverged");
}

/// The acceptance bar: `trace record dgemm` then `trace replay` must
/// reproduce the direct run's per-epoch instruction counts and ED²P
/// exactly — through an on-disk round trip of both encodings.
#[test]
fn record_replay_reproduces_direct_run_exactly() {
    let dir = fresh_dir("replay");
    let spec = workloads::build("dgemm", 0.05);

    let direct = {
        let mut m = DvfsManager::new(small_cfg(), &spec, Policy::PcStall, Objective::Ed2p);
        m.run(RunMode::Epochs(12), "dgemm")
    };

    let trace = capture_workload(&spec);
    for (file, binary) in [("dgemm.trace", false), ("dgemm.tracebin", true)] {
        let path = dir.join(file);
        trace.save(&path, binary).unwrap();
        let loaded = Trace::load(&path).unwrap();
        let mut m = DvfsManager::from_launches(
            small_cfg(),
            loaded.launches_scaled(1.0),
            loaded.rounds,
            Policy::PcStall,
            Objective::Ed2p,
        );
        let replayed = m.run(RunMode::Epochs(12), "dgemm");
        assert_identical_runs(&direct, &replayed, file);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Completion-mode ED²P must also replay exactly (fixed-work metric).
#[test]
fn completion_run_ed2p_replays_exactly() {
    let spec = workloads::build("comd", 0.02);
    let mode = RunMode::Completion { max_epochs: 5_000 };
    let direct = {
        let mut m = DvfsManager::new(small_cfg(), &spec, Policy::Static(4), Objective::Ed2p);
        m.run(mode, "comd")
    };
    let trace = capture_workload(&spec);
    let reloaded = Trace::decode(trace.to_text().as_bytes()).unwrap();
    let mut m = DvfsManager::from_launches(
        small_cfg(),
        reloaded.launches_scaled(1.0),
        reloaded.rounds,
        Policy::Static(4),
        Objective::Ed2p,
    );
    let replayed = m.run(mode, "comd");
    assert!(direct.completed && replayed.completed);
    assert_identical_runs(&direct, &replayed, "completion");
}

/// Trace workloads run through the sweep engine: a trace cell gets a
/// RunKey distinct from its catalog twin, and a warm rerun executes
/// zero simulations (cache-stable content-hash key).
#[test]
fn trace_cells_have_distinct_cache_stable_keys() {
    let dir = fresh_dir("cells");
    let trace = capture_workload(&workloads::build("dgemm", 0.05));
    let trace_path = dir.join("dgemm.trace");
    trace.save(&trace_path, false).unwrap();
    let trace_spec = format!("trace:{}", trace_path.display());

    let opts_with = |engine: Arc<Engine>| ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.clone(),
        engine,
        ..Default::default()
    };
    let cells = |opts: &ExpOptions| {
        vec![
            Cell::at(
                opts,
                "dgemm",
                Policy::Static(4),
                Objective::Ed2p,
                1000.0,
                RunMode::Epochs(3),
                1.0,
            ),
            Cell::at(
                opts,
                &trace_spec,
                Policy::Static(4),
                Objective::Ed2p,
                1000.0,
                RunMode::Epochs(3),
                1.0,
            ),
        ]
    };

    // cold: catalog and trace cells are distinct cells — both execute
    let cold = Arc::new(Engine::with_cache_dir(dir.join("cache")));
    let opts = opts_with(cold.clone());
    let results = run_cells(&opts, cells(&opts)).unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(cold.executed(), 2, "trace key must not collide with catalog key");
    assert_eq!(cold.deduped(), 0);

    // warm rerun: same trace file -> same content hash -> zero executions
    let warm = Arc::new(Engine::with_cache_dir(dir.join("cache")));
    let opts = opts_with(warm.clone());
    let rerun = run_cells(&opts, cells(&opts)).unwrap();
    assert_eq!(warm.executed(), 0, "warm trace rerun must be fully cached");
    assert_eq!(warm.cache_stats().hits, 2);
    for (a, b) in results.iter().zip(&rerun) {
        assert_eq!(a.total_instr, b.total_instr);
        assert_eq!(a.ed2p(), b.ed2p());
    }

    // edit the trace -> new content hash -> the trace cell recomputes
    let mut edited = trace.clone();
    edited.kernels[0].waves_per_cu += 1;
    edited.save(&trace_path, false).unwrap();
    let after = Arc::new(Engine::with_cache_dir(dir.join("cache")));
    let opts = opts_with(after.clone());
    run_cells(&opts, cells(&opts)).unwrap();
    assert_eq!(
        after.executed(),
        1,
        "edited trace must miss; unchanged catalog cell must hit"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt and truncated files must fail with an error, never a panic,
/// both at the format layer and through the harness path.
#[test]
fn corrupt_trace_files_error_cleanly() {
    let dir = fresh_dir("corrupt");
    let good = capture_workload(&workloads::build("comd", 0.05));

    // truncations of the binary form at a few spread offsets
    let bin = good.to_binary();
    for frac in [0usize, 1, 3, 7, 9] {
        let cut = bin.len() * frac / 10;
        let path = dir.join(format!("cut{frac}.trace"));
        std::fs::write(&path, &bin[..cut]).unwrap();
        let err = Trace::load(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("invalid trace"),
            "cut {frac}: {err:#}"
        );
    }

    // mangled text form
    let mut text = good.to_text();
    text = text.replace("valu", "vlau");
    let path = dir.join("mangled.trace");
    std::fs::write(&path, &text).unwrap();
    assert!(Trace::load(&path).is_err());

    // harness path: a bad trace spec fails the batch with an error
    let opts = ExpOptions {
        scale: Scale::Quick,
        out_dir: dir.clone(),
        ..Default::default()
    };
    let cell = Cell::at(
        &opts,
        &format!("trace:{}", path.display()),
        Policy::Static(4),
        Objective::Ed2p,
        1000.0,
        RunMode::Epochs(2),
        1.0,
    );
    let err = run_cells(&opts, vec![cell]).unwrap_err();
    assert!(format!("{err:#}").contains("invalid trace"), "{err:#}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// `synth:<seed>` specs and their saved trace files share one cache id.
#[test]
fn synth_spec_and_saved_file_agree() {
    use pcstall::workloads::WorkloadSource;
    let dir = fresh_dir("synth");
    let t = synthesize(5);
    let path = dir.join("synth5.trace");
    t.save(&path, true).unwrap();

    let from_seed = WorkloadSource::parse("synth:5").unwrap().resolve().unwrap();
    let from_file = WorkloadSource::parse(&format!("trace:{}", path.display()))
        .unwrap()
        .resolve()
        .unwrap();
    assert_eq!(from_seed.id, from_file.id);

    // and both lower to identical simulations
    let run = |r: &pcstall::workloads::ResolvedWorkload| {
        let (launches, rounds) = r.lower(0.5);
        let mut m = DvfsManager::from_launches(
            small_cfg(),
            launches,
            rounds,
            Policy::PcStall,
            Objective::Ed2p,
        );
        m.run(RunMode::Epochs(6), &r.display)
    };
    let a = run(&from_seed);
    let b = run(&from_file);
    assert_identical_runs(&a, &b, "synth vs file");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The checked-in example trace parses, validates, and simulates.
#[test]
fn example_trace_parses_and_runs() {
    let path = PathBuf::from(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/handwritten.trace"
    ));
    let t = Trace::load(&path).unwrap();
    assert_eq!(t.name, "hand-demo");
    t.validate().unwrap();
    let mut m = DvfsManager::from_launches(
        small_cfg(),
        t.launches_scaled(1.0),
        t.rounds,
        Policy::PcStall,
        Objective::Ed2p,
    );
    let r = m.run(RunMode::Epochs(4), "hand-demo");
    assert!(r.total_instr > 0.0);
}
