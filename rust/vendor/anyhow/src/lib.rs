//! Minimal offline shim of the `anyhow` API surface used by `pcstall`.
//!
//! The build environment has no registry access, so this crate provides
//! the subset of `anyhow` 1.x the workspace relies on:
//!
//! * [`Error`] — an opaque error value holding a context chain,
//! * [`Result`] — `Result<T, Error>` with a defaulted error type,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!
//! Semantics match `anyhow` where it matters here: `{}` displays the
//! outermost message, `{:#}` displays the whole chain separated by
//! `": "`, and `?` converts any `std::error::Error + Send + Sync`.

use std::fmt;

/// An opaque error: an outermost message plus its cause chain.
pub struct Error {
    /// Outermost context first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(context.to_string());
        chain.extend(self.chain);
        Error { chain }
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;
    use std::fmt::Display;

    /// Anything that can absorb an outer context into an [`Error`].
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| ext::StdError::ext_context(e, context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| ext::StdError::ext_context(e, f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_display() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn context_chains_on_anyhow_results() {
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        let e = f().context("while exploding").unwrap_err();
        assert_eq!(format!("{e:#}"), "while exploding: boom 1");
    }

    #[test]
    fn ensure_formats_messages() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
    }

    #[test]
    fn bare_ensure_stringifies_condition() {
        fn f(x: u32) -> Result<()> {
            ensure!(x == 0);
            Ok(())
        }
        let e = f(1).unwrap_err();
        assert!(format!("{e}").contains("x == 0"));
    }
}
